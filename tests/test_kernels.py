"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes and assert_allclose (here: exact
integer equality, these are integer datapaths) against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import si as si_mod
from repro.kernels import ops, ref
from repro.kernels.bsn_sort import bsn_sort_pallas
from repro.kernels.ternary_matmul import ternary_matmul_pallas


def _rand_case(seed, m, k, n, act_half=4):
    rng = np.random.default_rng(seed)
    x = rng.integers(-act_half, act_half + 1, (m, k)).astype(np.int8)
    w = rng.integers(-1, 2, (k, n)).astype(np.int8)
    return jnp.asarray(x), jnp.asarray(w)


# ---------------------------------------------------------------------------
# ternary matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (8, 16, 8, 8, 8, 16),
    (16, 32, 16, 8, 16, 16),
    (32, 64, 24, 16, 8, 32),     # n not multiple of bn -> exercised via ops
])
def test_matmul_kernel_exact_blocks(m, k, n, bm, bn, bk):
    if n % bn:
        pytest.skip("raw kernel requires padded shapes; ops test covers it")
    x, w = _rand_case(0, m, k, n)
    out = ternary_matmul_pallas(x, w, block_m=bm, block_n=bn, block_k=bk,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.ternary_matmul_ref(x, w)))


@given(st.integers(0, 10 ** 6),
       st.integers(1, 40),        # m
       st.integers(1, 70),        # k
       st.integers(1, 40))        # n
@settings(max_examples=12, deadline=None)
def test_matmul_ops_shape_sweep(seed, m, k, n):
    """ops wrapper handles ragged shapes via padding; forced kernel path."""
    x, w = _rand_case(seed, m, k, n)
    out = ops.ternary_matmul(x, w, min_flops_for_kernel=0,
                             block_m=8, block_n=8, block_k=8)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.ternary_matmul_ref(x, w)))


def test_matmul_batched_input():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-4, 5, (2, 3, 32)).astype(np.int8))
    w = jnp.asarray(rng.integers(-1, 2, (32, 16)).astype(np.int8))
    out = ops.ternary_matmul(x, w, min_flops_for_kernel=0,
                             block_m=8, block_n=8, block_k=8)
    assert out.shape == (2, 3, 16)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.ternary_matmul_ref(x, w)))


@given(st.integers(0, 10 ** 6))
@settings(max_examples=8, deadline=None)
def test_matmul_fused_si_epilogue(seed):
    """Fused SI in the kernel == reference epilogue == core.si design."""
    m, k, n, out_bsl = 16, 48, 8, 16
    x, w = _rand_case(seed, m, k, n)
    # per-channel monotone threshold tables in the sum_q domain
    sum_max = k * 4
    t_count = np.stack([
        si_mod.si_thresholds(si_mod.relu_fn, 2 * sum_max, out_bsl,
                             alpha_in=0.05 * (c + 1), alpha_out=0.1)
        for c in range(n)])
    t_q = jnp.asarray(t_count.astype(np.int64) - sum_max, jnp.int32)
    got = ops.ternary_matmul(x, w, t_q, min_flops_for_kernel=0,
                             block_m=8, block_n=8, block_k=16)
    expect = ref.ternary_matmul_ref(x, w, t_q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    # and the epilogue really is the SI: counts via core path
    sums = np.asarray(ref.ternary_matmul_ref(x, w))
    manual = np.stack([
        np.asarray(si_mod.apply_si_counts(jnp.asarray(sums[:, c] + sum_max),
                                          jnp.asarray(t_count[c])))
        for c in range(n)], axis=1) - out_bsl // 2
    np.testing.assert_array_equal(np.asarray(got), manual)


def test_matmul_int_dtype_int32_accumulate_no_overflow():
    """Large K accumulation stays exact (int32 path, not int8)."""
    k = 4096
    x = jnp.full((8, k), 4, jnp.int8)
    w = jnp.full((k, 8), 1, jnp.int8)
    out = ops.ternary_matmul(x, w, min_flops_for_kernel=0,
                             block_m=8, block_n=8, block_k=256)
    assert int(out[0, 0]) == 4 * k        # 16384 > int8/int16 range


# ---------------------------------------------------------------------------
# bsn sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,length,br", [(8, 16, 8), (16, 64, 8),
                                         (32, 128, 16), (8, 1024, 8)])
def test_sort_kernel_exact(r, length, br):
    rng = np.random.default_rng(r * length)
    x = jnp.asarray(rng.integers(0, 2, (r, length)).astype(np.int8))
    out = bsn_sort_pallas(x, block_r=br, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.bsn_sort_ref(x)))


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32, jnp.float32])
def test_sort_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-50, 50, (8, 64))).astype(dtype)
    out = bsn_sort_pallas(x, block_r=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.bsn_sort_ref(x)))


@given(st.integers(0, 10 ** 6), st.integers(1, 30), st.integers(2, 100))
@settings(max_examples=10, deadline=None)
def test_sort_ops_shape_sweep(seed, r, length):
    """ops wrapper: non-pow2 lengths, ragged rows, bit inputs."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2, (r, length)).astype(np.int8))
    out = ops.bsn_sort(x, block_r=8, min_rows_for_kernel=0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.bsn_sort_ref(x)))


def test_sort_matches_core_bsn():
    """Kernel == core.bsn.bitonic_sort (same network, two implementations)."""
    from repro.core import bsn as core_bsn
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 2, (16, 256)).astype(np.int8))
    a = bsn_sort_pallas(x, block_r=16, interpret=True)
    b = core_bsn.bitonic_sort(x, descending=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sort_preserves_popcount():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, 2, (64, 100)).astype(np.int8))
    out = ops.bsn_sort(x, min_rows_for_kernel=0, block_r=8)
    np.testing.assert_array_equal(np.asarray(out.sum(-1)),
                                  np.asarray(x.sum(-1)))


# ---------------------------------------------------------------------------
# flash attention kernel (forward / serving path)
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention import flash_attention_pallas


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk,causal", [
    (1, 64, 4, 2, 16, 16, 16, True),
    (2, 128, 8, 2, 32, 32, 16, True),
    (1, 64, 4, 4, 16, 32, 32, False),
    (2, 64, 6, 3, 8, 16, 16, True),      # GQA group 2, non-pow2 heads
])
def test_flash_pallas_vs_ref(B, S, Hq, Hkv, D, bq, bk, causal):
    key = jax.random.key(B * S + Hq)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hkv, D), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=2e-4, atol=2e-5)


def test_flash_pallas_matches_model_flash():
    """Kernel == the XLA flash scan used by the model zoo."""
    from repro.models.attention import flash_attention as xla_flash
    key = jax.random.key(0)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, Hkv, G, D = 2, 128, 2, 2, 16
    q = jax.random.normal(kq, (B, S, Hkv, G, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hkv, D), jnp.float32)
    a = xla_flash(q, k, v, causal=True, chunk=32)
    qf = q.reshape(B, S, Hkv * G, D)  # note: head-major grouping differs
    # reorder: model groups (Hkv, G); kernel expects q heads h where
    # kv = h // G -> q head index = hkv * G + g  == same ordering
    b = flash_attention_pallas(qf, k, v, causal=True, block_q=32,
                               block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a.reshape(B, S, Hkv * G, D)),
                               np.asarray(b), rtol=2e-4, atol=2e-5)


def test_flash_pallas_bf16():
    key = jax.random.key(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 64, 4, 32), jnp.bfloat16)
    k = jax.random.normal(kk, (1, 64, 4, 32), jnp.bfloat16)
    v = jax.random.normal(kv, (1, 64, 4, 32), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-2, atol=2e-2)

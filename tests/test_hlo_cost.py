"""The trip-count-aware HLO cost walk vs XLA cost_analysis ground truths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import analyze_hlo, parse_computations


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, w: x @ w, x, w)
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.05)


def test_scan_multiplies_by_trip_count():
    """THE reason this module exists: XLA counts while bodies once."""
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f_scan(w, x):
        return jax.lax.scan(lambda x, wi: (jnp.tanh(x @ wi), None), x, w)[0]

    def f_unroll(w, x):
        for i in range(10):
            x = jnp.tanh(x @ w[i])
        return x

    c_scan = _compile(f_scan, w, x)
    c_unroll = _compile(f_unroll, w, x)
    parsed_scan = analyze_hlo(c_scan.as_text())
    parsed_unroll = analyze_hlo(c_unroll.as_text())
    xla_scan = c_scan.cost_analysis()["flops"]
    # XLA undercounts the scan by ~10x; our walk does not
    assert parsed_scan.flops > 8 * xla_scan
    assert parsed_scan.flops == pytest.approx(parsed_unroll.flops, rel=0.1)
    assert parsed_unroll.flops == pytest.approx(
        c_unroll.cost_analysis()["flops"], rel=0.15)


def test_nested_scan():
    w = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def inner(x, ws):
        return jax.lax.scan(lambda x, wi: (x @ wi, None), x, ws)[0]

    def f(w, x):
        return jax.lax.scan(lambda x, ws: (inner(x, ws), None), x, w)[0]

    c = _compile(f, w, x)
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(12 * 2 * 8 * 32 * 32, rel=0.1)


def test_collectives_counted_with_groups():
    import os
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device (run under forced host devices)")


def test_parse_computations_shapes():
    x = jax.ShapeDtypeStruct((16, 16), jnp.bfloat16)
    c = _compile(lambda x: (x @ x).astype(jnp.float32).sum(), x)
    comps = parse_computations(c.as_text())
    assert "__entry__" in comps
    cost = analyze_hlo(c.as_text())
    assert cost.flops >= 2 * 16 * 16 * 16
    assert cost.bytes > 0

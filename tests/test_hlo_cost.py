"""The trip-count-aware HLO cost walk vs XLA cost_analysis ground truths.

Assertions are *structural*: they count op kinds over the parsed HLO
(``parse_computations``) and compare derived FLOPs, instead of matching
raw HLO text — the printer's surface syntax (typed vs bare operands,
metadata placement) drifts between XLA releases, the parsed instruction
stream does not.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import analyze_hlo, parse_computations


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_flops(compiled) -> float:
    """compiled.cost_analysis() is a dict on new jax, [dict] on older."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def _op_counts(text: str) -> dict[str, int]:
    """Op-kind histogram over every parsed computation."""
    counts: dict[str, int] = {}
    seen = set()
    for name, instrs in parse_computations(text).items():
        if name == "__entry__" or id(instrs) in seen:
            continue
        seen.add(id(instrs))
        for ins in instrs:
            counts[ins.op] = counts.get(ins.op, 0) + 1
    return counts


def test_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, w: x @ w, x, w)
    text = c.as_text()
    # structurally: exactly one dot, no loops
    ops = _op_counts(text)
    assert ops.get("dot", 0) + ops.get("fusion", 0) >= 1
    assert ops.get("while", 0) == 0
    cost = analyze_hlo(text)
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.05)


def test_scan_multiplies_by_trip_count():
    """THE reason this module exists: XLA counts while bodies once."""
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f_scan(w, x):
        return jax.lax.scan(lambda x, wi: (jnp.tanh(x @ wi), None), x, w)[0]

    def f_unroll(w, x):
        for i in range(10):
            x = jnp.tanh(x @ w[i])
        return x

    c_scan = _compile(f_scan, w, x)
    c_unroll = _compile(f_unroll, w, x)
    scan_text = c_scan.as_text()
    # structure: the scan lowered to exactly one counted while loop whose
    # body holds the single dot; the unrolled twin has 10 dots, no loop
    scan_ops = _op_counts(scan_text)
    unroll_ops = _op_counts(c_unroll.as_text())
    assert scan_ops.get("while", 0) == 1
    assert scan_ops.get("dot", 0) == 1
    assert unroll_ops.get("while", 0) == 0
    assert unroll_ops.get("dot", 0) == 10

    parsed_scan = analyze_hlo(scan_text)
    parsed_unroll = analyze_hlo(c_unroll.as_text())
    # XLA undercounts the scan by ~10x; our walk does not
    assert parsed_scan.flops > 8 * _xla_flops(c_scan)
    assert parsed_scan.flops == pytest.approx(parsed_unroll.flops, rel=0.1)
    assert parsed_unroll.flops == pytest.approx(
        _xla_flops(c_unroll), rel=0.15)


def test_nested_scan():
    w = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def inner(x, ws):
        return jax.lax.scan(lambda x, wi: (x @ wi, None), x, ws)[0]

    def f(w, x):
        return jax.lax.scan(lambda x, ws: (inner(x, ws), None), x, w)[0]

    c = _compile(f, w, x)
    text = c.as_text()
    assert _op_counts(text).get("while", 0) == 2   # outer + inner loop
    cost = analyze_hlo(text)
    assert cost.flops == pytest.approx(12 * 2 * 8 * 32 * 32, rel=0.1)


def test_collectives_counted_with_groups():
    import os
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device (run under forced host devices)")


def test_parse_computations_shapes():
    x = jax.ShapeDtypeStruct((16, 16), jnp.bfloat16)
    c = _compile(lambda x: (x @ x).astype(jnp.float32).sum(), x)
    comps = parse_computations(c.as_text())
    assert "__entry__" in comps
    # operand references resolve to parsed instruction names regardless of
    # whether the printer emits typed operands
    entry = comps["__entry__"]
    names = {i.name for i in entry}
    for ins in entry:
        for o in ins.operands:
            if ins.op in ("fusion", "call"):
                continue
            assert o in names or o.isdigit() or "{" in o or o == "", \
                (ins.op, o)
    cost = analyze_hlo(c.as_text())
    assert cost.flops >= 2 * 16 * 16 * 16
    assert cost.bytes > 0

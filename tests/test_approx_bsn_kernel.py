"""Three-way differential harness for the approximate BSN.

The chain proven here, per spec:

    approx_bsn_bits (circuit)  ==  approx_bsn_counts (oracle)
                               ==  fused Pallas kernel (interpret mode)

plus the dispatch layer's selection policy, the temporal-reuse kernel
against the chunked reference, the sc_layers integration, and the
paper_tnn spatial-temporal chunking regression.  Randomized specs come
from hypothesis (or the deterministic conftest fallback); degenerate
specs (no clip, stride 1, single stage) are pinned explicitly.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import coding
from repro.core.bsn import (ApproxBSNSpec, StageSpec, SubSampleSpec,
                            approx_bsn, approx_bsn_bits, approx_bsn_counts,
                            default_approx_spec, spatial_temporal_counts)
from repro.kernels import dispatch
from repro.kernels.approx_bsn import (approx_bsn_pallas,
                                      approx_bsn_temporal_pallas,
                                      validate_stages)

KERNEL = "pallas-interpret"       # compiled semantics, runs on CPU


# ---------------------------------------------------------------------------
# spec generation
# ---------------------------------------------------------------------------

def _random_spec(rng: np.random.Generator) -> ApproxBSNSpec:
    """A random VALID spec: 1-3 stages, pow2 groups/strides, legal clips."""
    n_stages = int(rng.integers(1, 4))
    groups = [int(2 ** rng.integers(1, 3)) for _ in range(n_stages)]
    in_bsl = int(2 ** rng.integers(1, 4))             # 2, 4, 8
    bsl, stages = in_bsl, []
    for g in groups:
        sorted_len = bsl * g
        stride = int(2 ** rng.integers(0, 3))         # 1, 2, 4
        max_out = sorted_len // stride
        out_bsl = int(rng.integers(1, max_out + 1))
        if (sorted_len - out_bsl * stride) % 2:       # clip must be symmetric
            out_bsl += -1 if out_bsl > 1 else 1
        kept = out_bsl * stride
        stages.append(StageSpec(g, SubSampleSpec((sorted_len - kept) // 2,
                                                 stride)))
        bsl = out_bsl
    return ApproxBSNSpec(width=math.prod(groups), in_bsl=in_bsl,
                         stages=tuple(stages))


def _three_way(spec: ApproxBSNSpec, seed: int, rows: int = 3):
    key = jax.random.key(seed)
    half = spec.in_bsl // 2
    levels = jax.random.randint(key, (rows, spec.width), -half, half + 1)
    bits = coding.encode_thermometer(levels, spec.in_bsl)
    counts = coding.counts_from_bits(bits)

    from_bits = coding.counts_from_bits(approx_bsn_bits(bits, spec))
    from_counts = approx_bsn_counts(counts, spec)
    from_kernel = dispatch.approx_bsn(counts, spec, backend=KERNEL,
                                      min_rows_for_kernel=0)
    return (np.asarray(from_bits), np.asarray(from_counts),
            np.asarray(from_kernel))


# ---------------------------------------------------------------------------
# three-way differential: randomized + degenerate specs
# ---------------------------------------------------------------------------

@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_three_way_random_specs(seed):
    rng = np.random.default_rng(seed)
    spec = _random_spec(rng)
    b, c, k = _three_way(spec, seed)
    np.testing.assert_array_equal(b, c)
    np.testing.assert_array_equal(c, k)


DEGENERATE = [
    # no clip, stride 1, single stage: the exact adder
    ApproxBSNSpec(8, 4, (StageSpec(8, SubSampleSpec(0, 1)),)),
    # single stage, clip only
    ApproxBSNSpec(8, 4, (StageSpec(8, SubSampleSpec(4, 1)),)),
    # single stage, stride only
    ApproxBSNSpec(8, 4, (StageSpec(8, SubSampleSpec(0, 4)),)),
    # multi-stage, all degenerate sub-samplers
    ApproxBSNSpec(16, 2, (StageSpec(4, SubSampleSpec(0, 1)),
                          StageSpec(4, SubSampleSpec(0, 1)))),
    # group=1 stages are legal plumbing (sort of a single code)
    ApproxBSNSpec(4, 4, (StageSpec(1, SubSampleSpec(1, 1)),
                         StageSpec(4, SubSampleSpec(0, 2)))),
]


@pytest.mark.parametrize("spec", DEGENERATE, ids=lambda s: str(s.stages))
def test_three_way_degenerate_specs(spec):
    b, c, k = _three_way(spec, seed=7, rows=4)
    np.testing.assert_array_equal(b, c)
    np.testing.assert_array_equal(c, k)


def test_fully_degenerate_is_exact_sum():
    spec = DEGENERATE[0]
    rng = np.random.default_rng(0)
    counts = jnp.asarray(rng.integers(0, spec.in_bsl + 1, (16, spec.width)))
    out = dispatch.approx_bsn(counts, spec, backend=KERNEL,
                              min_rows_for_kernel=0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(counts.sum(-1)))


# ---------------------------------------------------------------------------
# temporal-reuse kernel vs chunked reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cycles", [2, 4, 9])
def test_temporal_kernel_matches_reference(cycles):
    spec = ApproxBSNSpec(8, 4, (StageSpec(8, SubSampleSpec(clip=2,
                                                           stride=2)),))
    rng = np.random.default_rng(cycles)
    counts = jnp.asarray(
        rng.integers(0, spec.in_bsl + 1, (12, cycles * spec.width)),
        jnp.int32)
    got = dispatch.approx_bsn(counts, spec, cycles=cycles, backend=KERNEL,
                              min_rows_for_kernel=0)
    ref = spatial_temporal_counts(counts, spec, cycles)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_temporal_kernel_raw_grid_accumulation():
    """Raw kernel call (no dispatch): grid-revisited accumulation."""
    spec = ApproxBSNSpec(4, 2, (StageSpec(4, SubSampleSpec(0, 2)),))
    rng = np.random.default_rng(1)
    counts = jnp.asarray(rng.integers(0, 3, (8, 6 * 4)), jnp.int32)
    got = approx_bsn_temporal_pallas(
        counts, in_bsl=2, stages=dispatch.spec_stages(spec), cycles=6,
        block_r=8, interpret=True)
    ref = spatial_temporal_counts(counts, spec, 6)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------

def test_select_backend_policy(monkeypatch):
    # explicit argument always wins
    assert dispatch.select_backend(1, backend="pallas") == "pallas"
    # auto off-TPU: kernel for big row counts, reference for tiny
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert dispatch.select_backend(64) == "pallas-interpret"
    assert dispatch.select_backend(2) == "reference"
    # auto on TPU: compiled kernel for kernel-worthy row counts, but the
    # row threshold holds there too — a tiny pallas_call is all overhead
    # (regression: this used to return "pallas" unconditionally)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert dispatch.select_backend(64) == "pallas"
    assert dispatch.select_backend(2) == "reference"
    assert dispatch.select_backend(2, min_rows_for_kernel=1) == "pallas"
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    # scope override + restoration
    with dispatch.backend_scope("reference"):
        assert dispatch.select_backend(64) == "reference"
        with dispatch.backend_scope(None):      # None scope is a no-op
            assert dispatch.select_backend(64) == "reference"
    assert dispatch.select_backend(64) == "pallas-interpret"
    with pytest.raises(ValueError):
        dispatch.select_backend(1, backend="verilog")
    with pytest.raises(ValueError):
        dispatch.set_default_backend("verilog")


def test_dispatch_batched_and_1d_shapes():
    spec = default_approx_spec(16, 4)
    rng = np.random.default_rng(2)
    c3 = jnp.asarray(rng.integers(0, 5, (2, 5, 16)), jnp.int32)
    got = dispatch.approx_bsn(c3, spec, backend=KERNEL,
                              min_rows_for_kernel=0)
    assert got.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(approx_bsn_counts(c3, spec)))
    c1 = c3[0, 0]
    got1 = dispatch.approx_bsn(c1, spec, backend=KERNEL,
                               min_rows_for_kernel=0)
    assert got1.shape == ()
    assert int(got1) == int(approx_bsn_counts(c1, spec))


def test_core_front_door_routes_to_kernel():
    """core.bsn.approx_bsn is the same computation via dispatch."""
    spec = default_approx_spec(32, 2)
    rng = np.random.default_rng(3)
    c = jnp.asarray(rng.integers(0, 3, (16, 32)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(approx_bsn(c, spec, backend=KERNEL)),
        np.asarray(approx_bsn_counts(c, spec)))


def test_kernel_saturates_out_of_range_like_oracle():
    """Even with clip=0 the oracle saturates counts into [0, kept]; the
    kernel must clamp identically or backends diverge on garbage input."""
    spec = ApproxBSNSpec(8, 4, (StageSpec(8, SubSampleSpec(0, 2)),))
    bad = jnp.full((16, 8), 99, jnp.int32)          # far above in_bsl
    a = dispatch.approx_bsn(bad, spec, backend=KERNEL, min_rows_for_kernel=0)
    b = dispatch.approx_bsn(bad, spec, backend="reference")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("width,in_bsl", [(15, 5), (25, 3), (9, 9), (5, 2),
                                          (8, 2), (576, 2), (100, 8)])
def test_default_approx_spec_always_valid(width, in_bsl):
    """The designer must produce a constructible spec for ANY geometry,
    including odd sorted lengths (which admit no symmetric clip with an
    even stride)."""
    spec = default_approx_spec(width, in_bsl)       # would raise if invalid
    assert spec.out_bsl >= 1
    assert spec.scale & (spec.scale - 1) == 0       # pow2, re-alignable
    rng = np.random.default_rng(width)
    c = jnp.asarray(rng.integers(0, in_bsl + 1, (16, width)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dispatch.approx_bsn(c, spec, backend=KERNEL,
                                       min_rows_for_kernel=0)),
        np.asarray(approx_bsn_counts(c, spec)))


def test_validate_stages_rejects_bad_specs():
    with pytest.raises(ValueError):
        validate_stages(8, 4, ((3, 0, 1),))          # group doesn't divide
    with pytest.raises(ValueError):
        validate_stages(8, 4, ((8, 16, 1),))         # clip eats everything
    with pytest.raises(ValueError):
        validate_stages(8, 4, ((8, 1, 4),))          # stride doesn't divide
    with pytest.raises(ValueError):
        validate_stages(8, 4, ((4, 0, 1),))          # prod(groups) != width


# ---------------------------------------------------------------------------
# sc_layers integration: the approximate adder in the integer datapath
# ---------------------------------------------------------------------------

def _int_layer(seed, k, n):
    rng = np.random.default_rng(seed)
    x_q = jnp.asarray(rng.integers(-4, 5, (6, k)), jnp.int8)
    w_int = rng.integers(-1, 2, (k, n)).astype(np.int8)
    return x_q, {"w_int": w_int, "thresholds": None}


def test_sc_linear_int_approx_degenerate_is_exact():
    from repro.core.sc_layers import sc_linear_int, sc_linear_int_approx
    k, act_bsl = 32, 8
    x_q, ip = _int_layer(0, k, 8)
    spec = ApproxBSNSpec(k, act_bsl, (StageSpec(k, SubSampleSpec(0, 1)),))
    got = sc_linear_int_approx(ip, x_q, act_bsl, spec, backend=KERNEL)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(sc_linear_int(ip, x_q)))


def test_sc_linear_int_approx_kernel_equals_reference():
    from repro.core.sc_layers import sc_linear_int_approx
    k, act_bsl = 64, 8
    x_q, ip = _int_layer(1, k, 4)
    spec = default_approx_spec(16, act_bsl)
    a = sc_linear_int_approx(ip, x_q, act_bsl, spec, cycles=4,
                             backend=KERNEL)
    b = sc_linear_int_approx(ip, x_q, act_bsl, spec, cycles=4,
                             backend="reference")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_engine_pins_dispatch_backend():
    """ServeEngine(bsn_backend=...) scopes dispatch during traced calls
    and greedy generations are identical across backends (the adder is
    deterministic, only its executor changes)."""
    from repro.configs import get_arch
    from repro.models import init_params
    from repro.serving import ServeEngine
    cfg = get_arch("granite-3-2b").scaled(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        vocab_size=32, vocab_pad_multiple=32, dtype="float32")
    params = init_params(jax.random.key(0), cfg)

    with pytest.raises(ValueError):
        ServeEngine(params, cfg, bsn_backend="verilog")

    outs = {}
    for backend in (None, "reference"):
        eng = ServeEngine(params, cfg, max_slots=2, max_len=16,
                          bsn_backend=backend)
        eng.submit([1, 2, 3], max_new_tokens=3)
        done = eng.run_to_completion()
        assert len(done) == 1
        outs[backend] = done[0].generated
    assert outs[None] == outs["reference"]


# ---------------------------------------------------------------------------
# paper_tnn spatial-temporal chunking regression (Fig 12 on the chip's
# layer sizes)
# ---------------------------------------------------------------------------

def _tnn_folds():
    """(spec, cycles) combinations folding the TNN layer accumulations."""
    from repro.configs.paper_tnn import TNN_ACT_BSL, TNN_LAYERS
    folds = []
    for width, fold in ((TNN_LAYERS[0], 7), (TNN_LAYERS[1], 4),
                        (TNN_LAYERS[2], 4)):
        w = width // fold
        folds.append((default_approx_spec(w, TNN_ACT_BSL), fold))
        # exact (degenerate) fold of the same geometry
        folds.append((ApproxBSNSpec(
            w, TNN_ACT_BSL, (StageSpec(w, SubSampleSpec(0, 1)),)), fold))
    return folds


@pytest.mark.parametrize("spec,cycles", _tnn_folds(),
                         ids=lambda v: str(v))
def test_tnn_temporal_chunking_regression(spec, cycles):
    """Temporal path over T cycles == spatial pipeline per chunk, summed —
    and for degenerate specs == the exact sum of the concatenated input."""
    rng = np.random.default_rng(spec.width * cycles)
    total = cycles * spec.width
    counts = jnp.asarray(rng.integers(0, spec.in_bsl + 1, (4, total)),
                         jnp.int32)
    got = spatial_temporal_counts(counts, spec, cycles)
    chunks = counts.reshape(4, cycles, spec.width)
    expect = approx_bsn_counts(chunks, spec).sum(-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    # kernel agrees with the chunked reference
    kern = dispatch.approx_bsn(counts, spec, cycles=cycles, backend=KERNEL,
                               min_rows_for_kernel=0)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(got))
    if spec.scale == 1 and spec.out_bsl == spec.width * spec.in_bsl:
        # degenerate: temporal fold == spatial exact sum on the concat input
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(counts.sum(-1)))

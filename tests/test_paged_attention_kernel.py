"""Three-way differential harness for the paged-attention kernels.

The chain proven here, per the dispatch discipline:

    flash-decoding Pallas kernel (interpret mode)
        ==  XLA gather/scatter reference (kernels/ref.py)
        ==  sequential_generate token identity (dense-cache oracle)

on all three datapaths, plus: lengths straddling page boundaries
(``plen % page`` in {0, 1, page-1}), split-K widths, trash-page poison
invisibility under the kernel path, the attention backend scope /
``ServeEngine(attn_backend=...)`` pinning, and the dispatch-layer
regressions this PR fixes (TPU row threshold, zero-row approx_bsn).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.bsn import default_approx_spec
from repro.kernels import dispatch, ref
from repro.kernels.paged_attention import (paged_attn_decode_pallas,
                                           paged_attn_prefill_pallas)
from repro.models import init_params
from repro.serving import ServeEngine, sequential_generate

KERNEL = "pallas-interpret"       # compiled semantics, runs on CPU
POISON = 3.0e4


# ---------------------------------------------------------------------------
# kernel-level differential vs the XLA gather reference
# ---------------------------------------------------------------------------

def _paged_case(seed, S, Hkv, D, page, maxp):
    """Pools + per-slot page tables the way the allocator hands them out:
    page 0 reserved (trash), distinct physical pages per slot."""
    rng = np.random.default_rng(seed)
    n = S * maxp + 1
    kp = jnp.asarray(rng.standard_normal((n, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n, page, Hkv, D)), jnp.float32)
    tables = np.zeros((S, maxp), np.int32)
    for s in range(S):
        tables[s] = 1 + s * maxp + rng.permutation(maxp)
    return rng, kp, vp, jnp.asarray(tables)


@pytest.mark.parametrize("S,Hkv,G,D,page,maxp", [
    (3, 2, 2, 16, 8, 4),
    (1, 1, 1, 8, 4, 2),          # degenerate single-slot MHA
    (4, 2, 3, 32, 16, 3),        # non-pow2 GQA group
])
@pytest.mark.parametrize("num_splits", [1, 2, 3])
def test_decode_kernel_vs_reference(S, Hkv, G, D, page, maxp, num_splits):
    rng, kp, vp, tables = _paged_case(S * D, S, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray(rng.integers(0, maxp * page, S), jnp.int32)
    got = paged_attn_decode_pallas(q, kp, vp, tables, lengths,
                                   num_splits=num_splits, interpret=True)
    want = ref.paged_attn_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("rem", [0, 1, -1])
def test_decode_lengths_straddle_page_boundaries(rem):
    """plen % page in {0, 1, page-1}: the mask must cut exactly at the
    boundary whether the live window ends a page, just enters one, or
    stops one short."""
    S, Hkv, G, D, page, maxp = 3, 2, 2, 16, 8, 4
    rng, kp, vp, tables = _paged_case(7 + rem, S, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    # one slot per page multiple, offset by rem (mod page)
    lengths = jnp.asarray([(k * page + rem) % (maxp * page)
                           for k in (1, 2, 3)], jnp.int32)
    for num_splits in (1, 2):
        got = paged_attn_decode_pallas(q, kp, vp, tables, lengths,
                                       num_splits=num_splits,
                                       interpret=True)
        want = ref.paged_attn_decode_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6, err_msg=str(rem))


def test_decode_kernel_trash_page_poison_invisible():
    """Poison the trash page AND every page not referenced below the live
    length: the kernel output must be bit-identical to the clean run."""
    S, Hkv, G, D, page, maxp = 3, 2, 2, 16, 8, 4
    rng, kp, vp, tables = _paged_case(11, S, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray([5, page, 2 * page - 1], jnp.int32)
    clean = paged_attn_decode_pallas(q, kp, vp, tables, lengths,
                                     num_splits=2, interpret=True)
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    kp2[0] = POISON                                 # the trash page
    vp2[0] = POISON
    t = np.asarray(tables)
    for s in range(S):                              # pages past the length
        for j in range(int(lengths[s]) // page + 1, maxp):
            kp2[t[s, j]] = POISON
            vp2[t[s, j]] = POISON
    pois = paged_attn_decode_pallas(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                    tables, lengths, num_splits=2,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(pois))


@pytest.mark.parametrize("G,Hkv,Gq,D,page,C,start", [
    (2, 2, 2, 16, 8, 16, 0),
    (2, 2, 2, 16, 8, 16, 16),     # later chunk sees earlier pages
    (3, 1, 4, 8, 4, 8, 24),
    (1, 2, 1, 32, 8, 8, 8),
])
@pytest.mark.parametrize("block_q", [4, 16, 5])
def test_prefill_kernel_vs_reference(G, Hkv, Gq, D, page, C, start,
                                     block_q):
    maxp = (start + C) // page + 1
    rng, kp, vp, tables = _paged_case(G * C, G, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((G, C, Hkv, Gq, D)), jnp.float32)
    got = paged_attn_prefill_pallas(q, kp, vp, tables, start=start,
                                    block_q=block_q, interpret=True)
    want = ref.paged_attn_prefill_ref(q, kp, vp, tables, start)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_prefill_kernel_future_pages_poison_invisible():
    """Pages past the chunk's causal window never load: poisoning them
    (and the trash page) leaves the chunk output bit-identical."""
    G, Hkv, Gq, D, page, C, start = 2, 2, 2, 16, 8, 16, 8
    maxp = 6
    rng, kp, vp, tables = _paged_case(13, G, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((G, C, Hkv, Gq, D)), jnp.float32)
    clean = paged_attn_prefill_pallas(q, kp, vp, tables, start=start,
                                      block_q=8, interpret=True)
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    kp2[0] = POISON
    vp2[0] = POISON
    t = np.asarray(tables)
    seen = (start + C) // page
    for s in range(G):
        for j in range(seen, maxp):
            kp2[t[s, j]] = POISON
            vp2[t[s, j]] = POISON
    pois = paged_attn_prefill_pallas(q, jnp.asarray(kp2),
                                     jnp.asarray(vp2), tables,
                                     start=start, block_q=8,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(pois))


# ---------------------------------------------------------------------------
# engine-level: kernel path == reference path == sequential oracle
# ---------------------------------------------------------------------------

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
             vocab_pad_multiple=32, dtype="float32", attn_q_chunk=8)
CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]


def _engine_tokens(params, datapath, attn_backend, max_new=4, **kw):
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=8,
                      datapath=datapath, attn_backend=attn_backend, **kw)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_to_completion()
    assert len(done) == len(PROMPTS)
    return [r.generated for r in sorted(done, key=lambda r: r.rid)]


@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
def test_engine_kernel_three_way_token_identity(datapath):
    """The acceptance differential: decode AND chunked prefill through
    the interpret-mode Pallas kernels produce exactly the tokens of the
    XLA reference engine and of the dense-cache sequential oracle."""
    params = init_params(jax.random.key(0), CFG)
    kern = _engine_tokens(params, datapath, KERNEL)
    refe = _engine_tokens(params, datapath, "reference")
    seq = sequential_generate(params, CFG, PROMPTS, max_new_tokens=4,
                              max_len=32, datapath=datapath)
    assert kern == refe, datapath
    assert refe == seq, datapath


@pytest.mark.parametrize("fmt", ["int8", "sc"])
def test_engine_kernel_three_way_token_identity_compressed(fmt):
    """The compressed-pool third of the acceptance differential: the
    fused-dequant kernels (decode AND prefill, with the scale/residual
    pools riding the scalar-prefetch machinery) serve exactly the tokens
    of the dequant-fused XLA reference engine and of the same-format B=1
    paged sequential oracle."""
    datapath = "sc_int" if fmt == "sc" else "qat"
    params = init_params(jax.random.key(0), CFG)
    kern = _engine_tokens(params, datapath, KERNEL, kv_format=fmt)
    refe = _engine_tokens(params, datapath, "reference", kv_format=fmt)
    seq = sequential_generate(params, CFG, PROMPTS, max_new_tokens=4,
                              max_len=32, datapath=datapath,
                              kv_format=fmt)
    assert kern == refe, fmt
    assert refe == seq, fmt


def test_engine_auto_serves_the_kernel_off_tpu():
    """auto (attn_backend=None) routes this CPU container's serving
    shapes through the interpret kernel — and still matches the oracle."""
    params = init_params(jax.random.key(1), CFG)
    auto = _engine_tokens(params, "qat", None)
    seq = sequential_generate(params, CFG, PROMPTS, max_new_tokens=4,
                              max_len=32)
    assert auto == seq


def test_engine_kernel_path_poisoned_pools_never_attend():
    """The trash-page poison theorem under the kernel path: poison every
    pool position OUTSIDE the pages the requests legitimately own and
    the generated tokens must not move."""
    params = init_params(jax.random.key(0), CFG)
    want = _engine_tokens(params, "qat", KERNEL)

    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=8,
                      datapath="qat", attn_backend=KERNEL)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=4)
    # poison the whole pool (trash page included) before any prefill —
    # every live position gets overwritten by real K/V scatters, and
    # everything else must be masked by lengths/causality
    for per in eng.cache["periods"].values():
        for k in ("k_pages", "v_pages"):
            if k in per:
                per[k] = jnp.full_like(per[k], POISON)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    assert got == want


def test_engine_rejects_unknown_attn_backend():
    params = init_params(jax.random.key(0), CFG)
    with pytest.raises(ValueError):
        ServeEngine(params, CFG, attn_backend="verilog")


# ---------------------------------------------------------------------------
# dispatch layer: scope, thresholds, regressions
# ---------------------------------------------------------------------------

def test_attn_backend_scope_pins_and_restores():
    S, Hkv, G, D, page, maxp = 2, 2, 2, 16, 8, 2
    rng, kp, vp, tables = _paged_case(17, S, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray([3, 9], jnp.int32)
    want = ref.paged_attn_decode_ref(q, kp, vp, tables, lengths)
    with dispatch.attn_backend_scope("reference"):
        assert dispatch.get_attn_backend() == "reference"
        with dispatch.attn_backend_scope(None):     # no-op, not a reset
            assert dispatch.get_attn_backend() == "reference"
        got = dispatch.paged_attn_decode(q, kp, vp, tables, lengths)
    assert dispatch.get_attn_backend() is None
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the attention scope never leaks into the BSN chain and vice versa
    with dispatch.attn_backend_scope("reference"):
        assert dispatch.get_default_backend() is None
    with dispatch.backend_scope("reference"):
        assert dispatch.get_attn_backend() is None
    with pytest.raises(ValueError):
        dispatch.set_attn_backend("verilog")


def test_paged_dispatch_row_threshold():
    """Tiny paged shapes take the reference under auto — same policy as
    the BSN chain, including on (monkeypatched) TPU."""
    S, Hkv, G, D, page, maxp = 1, 1, 1, 8, 4, 2
    rng, kp, vp, tables = _paged_case(19, S, Hkv, D, page, maxp)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray([2], jnp.int32)
    # rows = S*Hkv*G = 1 < 8 -> reference; result must equal the oracle
    got = dispatch.paged_attn_decode(q, kp, vp, tables, lengths)
    want = ref.paged_attn_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_approx_bsn_zero_rows_short_circuits_to_reference():
    """Regression: a zero-size leading batch dim used to reach the
    pallas path as a degenerate 0-row pallas_call.  Now it returns the
    empty reference result on EVERY backend."""
    spec = default_approx_spec(width=16, in_bsl=4)
    empty = jnp.zeros((0, spec.width), jnp.int32)
    for backend in (None, "pallas-interpret", "reference"):
        out = dispatch.approx_bsn(empty, spec, backend=backend)
        assert out.shape == (0,), backend
    # zero rows hiding under a nonzero leading dim
    empty3 = jnp.zeros((2, 0, spec.width), jnp.int32)
    out = dispatch.approx_bsn(empty3, spec, backend="pallas-interpret")
    assert out.shape == (2, 0)
    # temporal variant too
    empty_t = jnp.zeros((0, 2 * spec.width), jnp.int32)
    out = dispatch.approx_bsn(empty_t, spec, cycles=2,
                              backend="pallas-interpret")
    assert out.shape == (0,)

"""Chunk-resumable recurrent prefill: split == one-shot, BITWISE.

The serving engine's chunked paged prefill stands on one property of
``mamba_prefill_chunk`` / ``rwkv_tmix_prefill_chunk`` /
``rwkv_cmix_prefill_chunk``: running a prompt in chunks of ANY size,
threading the carried state (conv tail + SSM/WKV state + token shifts),
replays the identical per-token op sequence — so outputs and final
state equal the one-shot call bit for bit, and the engine's batched
prefill can be token-identical to ``sequential_generate`` even on the
fake-quant lattice where float ties decide argmax.

Second property: the ``valid`` mask (right-padded lanes in a prefill
bucket) freezes state by exact select — padded garbage is inert, and a
masked run equals the truncated run bitwise.

Both are checked with ``np.array_equal`` (no tolerance): these are
order-exactness contracts, not approximations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LayerSpec, get_arch
from repro.models.mamba import (mamba_init, mamba_prefill_chunk,
                                mamba_state_init)
from repro.models.rwkv6 import (rwkv_cmix_init, rwkv_cmix_prefill_chunk,
                                rwkv_state_init, rwkv_tmix_init,
                                rwkv_tmix_prefill_chunk)

MAMBA_CFG = get_arch("jamba-1.5-large-398b").scaled(
    period=(LayerSpec("mamba", "dense"),), n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
    vocab_pad_multiple=32, dtype="float32", mamba_d_state=8)
RWKV_CFG = get_arch("rwkv6-7b").scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=64, vocab_pad_multiple=32, dtype="float32",
    rwkv_head_dim=16)

B, S = 2, 13                 # S coprime with every split size below


def _mixers():
    key = jax.random.key(7)
    mam = (mamba_init(key, MAMBA_CFG),
           lambda p, x, st, valid=None: mamba_prefill_chunk(
               p, x, MAMBA_CFG, st, valid=valid),
           mamba_state_init(MAMBA_CFG, B))
    tmix = (rwkv_tmix_init(key, RWKV_CFG),
            lambda p, x, st, valid=None: rwkv_tmix_prefill_chunk(
                p, x, RWKV_CFG, st, valid=valid),
            rwkv_state_init(RWKV_CFG, B))
    cmix = (rwkv_cmix_init(key, RWKV_CFG),
            lambda p, x, st, valid=None: rwkv_cmix_prefill_chunk(
                p, x, RWKV_CFG, st, valid=valid),
            {"shift": jnp.zeros((B, RWKV_CFG.d_model), jnp.float32)})
    return {"mamba": mam, "rwkv_tmix": tmix, "rwkv_cmix": cmix}


def _x(key=5):
    return jax.random.normal(jax.random.key(key), (B, S, 64), jnp.float32)


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@pytest.mark.parametrize("mixer", ["mamba", "rwkv_tmix", "rwkv_cmix"])
@pytest.mark.parametrize("csize", [1, 4, S - 1])
def test_chunk_split_bitwise_equals_oneshot(mixer, csize):
    p, fn, state0 = _mixers()[mixer]
    x = _x()
    y_ref, st_ref = fn(p, x, state0)
    st, ys = state0, []
    for a in range(0, S, csize):
        y, st = fn(p, x[:, a:a + csize], st)
        ys.append(y)
    assert np.array_equal(np.asarray(jnp.concatenate(ys, axis=1)),
                          np.asarray(y_ref)), (mixer, csize)
    assert _tree_equal(st, st_ref), (mixer, csize)


@pytest.mark.parametrize("mixer", ["mamba", "rwkv_tmix", "rwkv_cmix"])
def test_masked_padding_is_inert_and_prefix_exact(mixer):
    """Positions past ``valid`` must not touch the carried state: two
    runs with different garbage in the padded region agree bitwise, and
    both equal the truncated (no-padding) run."""
    p, fn, state0 = _mixers()[mixer]
    n = 5
    x = _x()
    valid = (jnp.arange(S) < n)[None, :] & jnp.ones((B, 1), bool)
    y1, st1 = fn(p, x, state0, valid=valid)
    x2 = x.at[:, n:].set(jax.random.normal(jax.random.key(11),
                                           (B, S - n, 64), jnp.float32))
    y2, st2 = fn(p, x2, state0, valid=valid)
    assert _tree_equal(st1, st2), mixer
    # outputs at valid positions are garbage-independent too (the
    # engine only consumes valid rows, but the cheap guarantee is full)
    assert np.array_equal(np.asarray(y1[:, :n]), np.asarray(y2[:, :n]))
    _, st3 = fn(p, x[:, :n], state0)
    assert _tree_equal(st1, st3), mixer


@pytest.mark.parametrize("mixer", ["mamba", "rwkv_tmix", "rwkv_cmix"])
def test_fully_masked_chunk_is_identity_on_state(mixer):
    """A chunk with zero valid tokens (a short lane deep in a long
    bucket) must pass the state through untouched, bitwise."""
    p, fn, state0 = _mixers()[mixer]
    x = _x()
    st_in = jax.tree.map(jnp.asarray, fn(p, x, state0)[1])  # nontrivial
    _, st_out = fn(p, _x(9), st_in, valid=jnp.zeros((B, S), bool))
    assert _tree_equal(st_in, st_out), mixer


def test_engine_chunk_size_one_matches_sequential():
    """page_size=1 drives the engine's prefill chunk down to a single
    token — the most boundary-heavy split possible — and tokens must
    still match the oracle (conv tail crossed at EVERY position)."""
    from repro.models import init_params
    from repro.serving import ServeEngine, sequential_generate
    params = init_params(jax.random.key(0), MAMBA_CFG)
    prompts = [[1, 2, 3, 4, 5], [6, 7]]
    eng = ServeEngine(params, MAMBA_CFG, max_slots=2, max_len=16,
                      page_size=1, prefill_chunk=1)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    ref = sequential_generate(params, MAMBA_CFG, prompts,
                              max_new_tokens=4, max_len=16)
    assert got == ref

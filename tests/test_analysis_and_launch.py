"""Analysis/report + launch-layer unit tests (no 512-device compile)."""

import json
import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.report import dryrun_table, fmt_s, roofline_table
from repro.analysis.roofline import V5E, count_params, model_flops
from repro.configs import SHAPES, get_arch, list_archs, shape_by_name
from repro.distributed.sharding import MeshRules, constrain, current_rules
from repro.launch.dryrun import all_cells, cell_skip_reason


# ---------------------------------------------------------------------------
# skip rules == DESIGN.md §4 cell accounting
# ---------------------------------------------------------------------------

def test_cell_accounting():
    cells = all_cells()
    assert len(cells) == 40                          # 10 archs x 4 shapes
    skips = [(a, s) for a, s in cells
             if cell_skip_reason(get_arch(a), shape_by_name(s))]
    assert len(skips) == 9                           # 7 long_500k + 2 hubert
    assert ("rwkv6-7b", "long_500k") not in skips
    assert ("jamba-1.5-large-398b", "long_500k") not in skips
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("granite-3-2b", "long_500k") in skips


def test_param_counts_sane():
    """Analytic param counts land near the arch names' billions."""
    expect = {"stablelm-1.6b": (1.2, 2.2), "granite-3-2b": (1.8, 3.0),
              "nemotron-4-15b": (12, 18), "phi3-medium-14b": (12, 16),
              "rwkv6-7b": (6, 9), "dbrx-132b": (110, 150),
              "qwen3-moe-235b-a22b": (200, 260),
              "jamba-1.5-large-398b": (330, 420),
              "llava-next-34b": (30, 38), "hubert-xlarge": (0.7, 1.3)}
    for name, (lo, hi) in expect.items():
        n = count_params(get_arch(name)) / 1e9
        assert lo <= n <= hi, (name, n)


def test_active_params_moe():
    cfg = get_arch("qwen3-moe-235b-a22b")
    active = count_params(cfg, active_only=True) / 1e9
    assert 15 <= active <= 30, active                # "a22b"


def test_model_flops_kinds():
    cfg = get_arch("granite-3-2b")
    tr = model_flops(cfg, shape_by_name("train_4k"))
    pf = model_flops(cfg, shape_by_name("prefill_32k"))
    dc = model_flops(cfg, shape_by_name("decode_32k"))
    assert tr == pytest.approx(6 * count_params(cfg, True) * 4096 * 256)
    assert pf == pytest.approx(2 * count_params(cfg, True) * 32768 * 32)
    assert dc == pytest.approx(2 * count_params(cfg, True) * 128)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_mesh_rules_resolve_filters_missing_axes():
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1], object).reshape(1), ("data",))
    rules = MeshRules(mesh=mesh, mapping={"batch": ("pod", "data"),
                                          "model": ("model",)})
    spec = rules.resolve(("batch", None, "model"))
    assert spec == P("data", None, None)            # pod+model filtered out


def test_constrain_is_identity_without_rules():
    assert current_rules() is None
    x = jax.numpy.ones((4, 4))
    y = constrain(x, "batch", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# report generation from the real sweep records
# ---------------------------------------------------------------------------

RECORDS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


@pytest.mark.skipif(not os.path.isdir(RECORDS), reason="no sweep records")
def test_report_from_real_records():
    recs = []
    for fn in sorted(os.listdir(RECORDS))[:12]:
        with open(os.path.join(RECORDS, fn)) as f:
            recs.append(json.load(f))
    table = dryrun_table(recs)
    assert table.count("|") > 20
    rtab = roofline_table(recs)
    assert "bottleneck" in rtab


def test_fmt_s():
    assert fmt_s(0.5e-6).endswith("us")
    assert fmt_s(0.005).endswith("ms")
    assert fmt_s(2.0).endswith("s")


# ---------------------------------------------------------------------------
# property test: SI threshold design is correct for ANY monotone step fn
# ---------------------------------------------------------------------------

# degrade (skip) rather than error if neither the real hypothesis nor the
# conftest fallback shim is importable
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st


@given(st.lists(st.integers(0, 16), min_size=9, max_size=9))
@settings(max_examples=30, deadline=None)
def test_si_thresholds_any_monotone_function(deltas):
    """Invariant: for any monotone out_count table, apply_si_counts
    reproduces it exactly at every input count."""
    import jax.numpy as jnp
    from repro.core.si import apply_si_counts, si_thresholds_from_counts
    oc = np.minimum(np.cumsum(np.asarray(deltas) % 4), 16)
    t = si_thresholds_from_counts(oc, 16)
    got = np.asarray(apply_si_counts(jnp.arange(len(oc)), jnp.asarray(t)))
    np.testing.assert_array_equal(got, oc)

"""Paged KV cache: allocator properties + batched-vs-sequential decode.

Three layers of guarantees, bottom-up:

1. ``PageAllocator``/``PageTable`` host bookkeeping: alloc/free
   round-trips, all-or-nothing allocation, the trash page is never
   handed out (property tests via hypothesis or the conftest fallback).
2. No cross-request leakage: after requests finish and their pages are
   recycled to *new* requests, the new requests' tokens are identical
   to a fresh engine's — stale page contents are dead by construction
   (length-masked reads).
3. The differential theorem the engine stands on: batched paged decode
   == per-request sequential decode (the seed execution model),
   token for token, across the zoo's layer types and datapaths —
   recurrent mixers included, through the chunked state-carrying paged
   prefill (prefill runs the per-token recurrence, so any chunk split
   is bit-identical to the exact-length call; ``sc_int`` is bit-exact
   by integer accumulation on every arch).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import LayerSpec, get_arch
from repro.models import init_params
from repro.serving import (PageAllocator, PageTable, SamplingParams,
                           ServeEngine, kv_page_bytes, sequential_generate)
from repro.serving.paging import TRASH_PAGE, pad_pow2, pages_needed

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)
CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [10, 11, 12, 13, 14]]

# the recurrent zoo, quantized (sc_int is bit-exact on these too now
# that prefill is order-exact at every chunk split)
RECURRENT = {
    "mamba": get_arch("jamba-1.5-large-398b").scaled(
        period=(LayerSpec("mamba", "dense"),), n_layers=2, **SCALE,
        mamba_d_state=8),
    "rwkv6": get_arch("rwkv6-7b").scaled(
        n_layers=2, **{**SCALE, "n_kv_heads": 4}),
    "jamba": get_arch("jamba-1.5-large-398b").scaled(
        n_layers=8, **SCALE, mamba_d_state=8, n_experts=4,
        n_experts_per_tok=2, moe_capacity_factor=2.0),
}


def _run_engine(params, cfg, prompts, max_new=5, sampling=None, **kw):
    eng = ServeEngine(params, cfg, **kw)
    sps = sampling if sampling is not None else [None] * len(prompts)
    for p, sp in zip(prompts, sps):
        eng.submit(p, max_new_tokens=max_new, sampling=sp)
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    return [r.generated for r in sorted(done, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# 1. allocator properties
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(1, 5), min_size=1, max_size=8),
       st.integers(8, 64))
@settings(max_examples=20, deadline=None)
def test_alloc_free_roundtrip(sizes, num_pages):
    a = PageAllocator(num_pages)
    start_free = a.free_count
    assert start_free == num_pages - 1          # page 0 reserved
    held = []
    for n in sizes:
        got = a.alloc(n)
        if got is None:
            assert n > a.free_count             # only fails when short
            continue
        assert len(got) == n
        assert TRASH_PAGE not in got            # trash never handed out
        held.append(got)
    flat = [p for g in held for p in g]
    assert len(set(flat)) == len(flat)          # no page owned twice
    for g in held:
        a.free(g)
    assert a.free_count == start_free           # round-trip restores all


def test_double_free_rejected():
    a = PageAllocator(8)
    g = a.alloc(2)
    a.free(g)
    with pytest.raises(ValueError):
        a.free(g)
    with pytest.raises(ValueError):
        a.free([TRASH_PAGE])


def test_fragmentation_interleaved_alloc_free_to_exhaustion():
    """Long interleaved alloc/free churn fragments the LIFO free list;
    the invariants must hold at every step — no page owned twice, the
    trash page never escapes, free_count + owned == capacity — and a
    full drain after driving the pool to exhaustion restores the exact
    starting capacity (no page leaked, none minted)."""
    cap = 16
    a = PageAllocator(cap + 1)
    held = []
    rng = np.random.default_rng(5)
    for _ in range(200):
        if held and rng.integers(3) == 0:
            a.free(held.pop(int(rng.integers(len(held)))))
        n = int(rng.integers(1, 5))
        got = a.alloc(n)
        if got is None:
            assert n > a.free_count         # all-or-nothing, only short
        else:
            held.append(got)
        owned = [p for g in held for p in g]
        assert len(set(owned)) == len(owned)
        assert TRASH_PAGE not in owned
        assert a.free_count + len(owned) == cap
    while (got := a.alloc(1)) is not None:   # exhaust
        held.append(got)
    assert a.free_count == 0 and a.alloc(1) is None
    for g in held:
        a.free(g)
    assert a.free_count == cap


def test_alloc_fail_leaves_pool_intact():
    """A failing alloc must return None WITHOUT leaking partially
    grabbed pages: the free count is untouched and a smaller request
    still succeeds."""
    a = PageAllocator(6)                     # 5 usable
    g = a.alloc(3)
    before = a.free_count
    assert a.alloc(3) is None                # only 2 free
    assert a.free_count == before
    g2 = a.alloc(2)
    assert g2 is not None and not set(g2) & set(g)
    a.free(g)
    a.free(g2)
    assert a.free_count == 5


@pytest.mark.parametrize("fmt,datapath", [("fp", "qat"), ("int8", "qat"),
                                          ("sc", "sc_int")])
def test_pool_device_bytes_match_page_accounting(fmt, datapath):
    """The allocator's page count times ``kv_page_bytes`` equals the
    actual device bytes of the attention pools (codes + scales +
    residuals) per layer — the analytic capacity model the bench
    records is exact, not an estimate."""
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=8,
                      datapath=datapath, kv_format=fmt)
    per_page = kv_page_bytes(8, CFG.n_kv_heads,
                             CFG.d_model // CFG.n_heads, fmt)
    pool_keys = ("k_pages", "v_pages", "k_scale", "v_scale",
                 "k_resid", "v_resid")
    for entry in eng.cache["periods"].values():
        if "k_pages" not in entry:
            continue
        n_periods, num_pages = entry["k_pages"].shape[:2]
        assert num_pages == eng.allocator.num_pages
        got = sum(entry[k].nbytes for k in pool_keys if k in entry)
        assert got == n_periods * num_pages * per_page, fmt


@given(st.integers(0, 40), st.integers(0, 40))
@settings(max_examples=20, deadline=None)
def test_page_table_ensure_monotonic(l1, l2):
    a = PageAllocator(64)
    t = PageTable(page_size=4)
    assert t.ensure(l1, a) and t.ensure(l2, a)
    # table covers the running max, exactly (never shrinks, never over-
    # allocates), and releases everything it took
    assert len(t.pages) == pages_needed(max(l1, l2), 4)
    t.release(a)
    assert a.free_count == 63


def test_padded_table_is_trash_padded():
    a = PageAllocator(16)
    t = PageTable(page_size=4)
    t.ensure(6, a)                              # 2 pages
    padded = t.padded(8)
    assert list(padded[:2]) == t.pages
    assert all(p == TRASH_PAGE for p in padded[2:])
    with pytest.raises(ValueError):
        t.padded(1)


def test_pad_pow2_buckets():
    assert [pad_pow2(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert pad_pow2(1, lo=16) == 16


def test_pad_pow2_always_pow2():
    """The pow2-bucket contract: whatever the bounds, the bucket is a
    power of two >= n (a non-pow2 bucket would mint a fresh jit trace
    per odd size; a bucket < n would under-allocate the lane buffers)."""
    for n in range(1, 20):
        for lo in (1, 3, 4, 16):
            for hi in (None, 3, 4, 6, 8, 31):
                b = pad_pow2(n, lo=lo, hi=hi)
                assert b & (b - 1) == 0, (n, lo, hi, b)
                assert b >= n, (n, lo, hi, b)
    # hi is clamped DOWN to a pow2 (6 -> 4), lo rounded up (3 -> 4)
    assert pad_pow2(3, hi=6) == 4
    assert pad_pow2(4, hi=6) == 4
    assert pad_pow2(2, hi=3) == 2
    assert pad_pow2(1, lo=3) == 4
    # the old bug: min(b, hi) returned a non-pow2 hi verbatim
    assert pad_pow2(3, hi=3) == 4
    # soft cap: no pow2 <= hi can hold n -> next pow2 above n anyway
    assert pad_pow2(5, hi=6) == 8
    assert pad_pow2(6, hi=6) == 8


# ---------------------------------------------------------------------------
# 2. recycling: no cross-request leakage
# ---------------------------------------------------------------------------

def test_page_recycling_no_leakage():
    """Run a wave of requests to completion, then a second wave through
    the SAME engine — its pages are recycled physical pages.  The second
    wave must match a fresh engine serving it alone."""
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=8)
    wave1 = PROMPTS[:2]
    wave2 = [[9, 8, 7, 6, 5], [3, 1], [2, 2, 2]]
    for p in wave1:
        eng.submit(p, max_new_tokens=6)
    eng.run_to_completion()
    used_before = eng.allocator.free_count
    for p in wave2:
        eng.submit(p, max_new_tokens=6)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    assert eng.allocator.free_count == used_before   # all pages returned
    fresh = _run_engine(init_params(jax.random.key(0), CFG), CFG, wave2,
                        max_new=6, max_slots=2, max_len=32, page_size=8)
    assert got == fresh


def test_unservable_prompt_rejected_at_submit():
    """A prompt that could never fit the pool (even empty) must fail
    loudly at submit, not spin forever in the admission queue."""
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=31, page_size=4,
                      num_pages=8)                 # 7 usable pages
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(30)))                # needs 8 pages
    eng.submit(list(range(20)))                    # 6 pages: fine


def test_empty_prompt_rejected_at_submit():
    """An empty prompt would reach prefill as a (1, 0) token batch and
    blow up deep inside the model; it must fail at the API boundary."""
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    eng.submit([1])                                # 1 token: fine


def test_boundary_prompts_match_sequential():
    """Prompts of length max_len-2 and max_len-1: the done-logic boundary
    (`_len >= max_len - 1`, consolidated in `_check_done`) must agree
    with sequential_generate's `length < max_len - 1` loop condition —
    exactly 2 and 1 generated tokens respectively."""
    params = init_params(jax.random.key(0), CFG)
    max_len = 16
    prompts = [list(range(1, max_len - 1)),        # max_len - 2 tokens
               list(range(1, max_len))]            # max_len - 1 tokens
    got = _run_engine(params, CFG, prompts, max_new=8, max_slots=2,
                      max_len=max_len, page_size=4)
    ref = sequential_generate(params, CFG, prompts, max_new_tokens=8,
                              max_len=max_len)
    assert got == ref
    assert [len(g) for g in got] == [2, 1]
    with pytest.raises(ValueError, match="exceeds"):
        ServeEngine(params, CFG, max_slots=2, max_len=max_len,
                    page_size=4).submit(list(range(max_len)))


def test_non_pow2_max_slots_matches_sequential():
    """max_slots=3 (non-pow2): slot buckets must still be powers of two
    (the pad_pow2 fix) and tokens must match the oracle."""
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=3, max_len=32, page_size=8)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=5)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    ref = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                              max_len=32)
    assert got == ref


def test_preemption_under_page_pressure():
    """A pool too small for all admitted requests forces preemption
    (free + requeue + re-prefill); greedy decode is deterministic so the
    final tokens still match the sequential oracle."""
    params = init_params(jax.random.key(0), CFG)
    # 2 slots x up to 24 tokens needs 6 pages of 8; give it 4 + trash
    eng = ServeEngine(params, CFG, max_slots=2, max_len=24, page_size=8,
                      num_pages=5)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13]]
    for p in prompts:
        eng.submit(p, max_new_tokens=12)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    ref = sequential_generate(params, CFG, prompts, max_new_tokens=12,
                              max_len=24)
    assert got == ref


# ---------------------------------------------------------------------------
# 3. differential: batched paged == sequential, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
def test_batched_equals_sequential_sc_datapaths(datapath):
    params = init_params(jax.random.key(0), CFG)
    got = _run_engine(params, CFG, PROMPTS, max_new=5, max_slots=3,
                      max_len=32, page_size=8, datapath=datapath)
    ref = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                              max_len=32, datapath=datapath)
    assert got == ref, datapath


def test_batched_equals_sequential_mixed_lengths_and_buckets():
    """Length mix spanning several page/slot buckets + late admissions."""
    params = init_params(jax.random.key(1), CFG)
    prompts = [[1], [2, 3, 4, 5, 6, 7, 8, 9, 10],
               [11, 12], [13, 14, 15, 16, 17], [18] * 12]
    got = _run_engine(params, CFG, prompts, max_new=8, max_slots=2,
                      max_len=32, page_size=4)
    ref = sequential_generate(params, CFG, prompts, max_new_tokens=8,
                              max_len=32)
    assert got == ref


@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
@pytest.mark.parametrize("arch", sorted(RECURRENT))
def test_chunked_recurrent_batched_equals_sequential(arch, datapath):
    """The tentpole differential: mamba, rwkv6 and the jamba hybrid now
    prefill through the SAME batched chunked paged path as attention
    (no exact-length fallback), and stay token-identical to the
    sequential oracle on every datapath.  Holds because prefill runs
    the per-token recurrence with carried state — any chunk split
    replays the identical op sequence, so even sc_int's lattice ties
    break the same way on both sides."""
    cfg = RECURRENT[arch]
    params = init_params(jax.random.key(0), cfg)
    got = _run_engine(params, cfg, PROMPTS[:3], max_new=4, max_slots=2,
                      max_len=32, page_size=8, datapath=datapath)
    ref = sequential_generate(params, cfg, PROMPTS[:3], max_new_tokens=4,
                              max_len=32, datapath=datapath)
    assert got == ref, (arch, datapath)


def test_chunked_recurrent_sampled_matches_sequential():
    """Seeded stochastic decode over the chunked recurrent prefill: the
    (seed, position) streams don't care how the prompt was chunked."""
    sampling = [SamplingParams(temperature=0.9, top_p=0.9, seed=3 + i)
                for i in range(3)]
    for arch in ("rwkv6", "jamba"):
        cfg = RECURRENT[arch]
        params = init_params(jax.random.key(0), cfg)
        got = _run_engine(params, cfg, PROMPTS[:3], max_new=4,
                          sampling=sampling, max_slots=2, max_len=32,
                          page_size=8)
        ref = sequential_generate(params, cfg, PROMPTS[:3],
                                  max_new_tokens=4, max_len=32,
                                  sampling=sampling)
        greedy = sequential_generate(params, cfg, PROMPTS[:3],
                                     max_new_tokens=4, max_len=32)
        assert got == ref, arch
        assert got != greedy, f"{arch}: sampling degenerated to greedy"


def test_chunked_equals_exact_prefill_oracle():
    """``prefill_mode="exact"`` (the retired per-request exact-length
    fallback, kept as a debug oracle) and the default chunked path
    produce identical tokens — multi-chunk prompts included."""
    prompts = [[(3 * i + j) % 64 for j in range(n)]
               for i, n in enumerate([23, 1, 17, 9])]
    for arch in ("mamba", "rwkv6"):
        cfg = RECURRENT[arch]
        params = init_params(jax.random.key(0), cfg)
        kw = dict(max_new=4, max_slots=2, max_len=32, page_size=4,
                  prefill_chunk=4)
        chunked = _run_engine(params, cfg, prompts, **kw)
        exact = _run_engine(params, cfg, prompts, prefill_mode="exact",
                            **kw)
        assert chunked == exact, arch


def test_mamba_conv_tail_across_chunk_boundaries():
    """PR 2's pad-then-crop fix covered one exact-length call; a prompt
    split into chunks must reproduce the IDENTICAL mixer output at every
    boundary (the carried conv tail supplies the k-1 pre-conv inputs the
    next chunk's conv window needs).  Chunk sizes 1, page_size, and
    prompt_len-1, compared bitwise — output, SSM state and tail."""
    from repro.models.mamba import (mamba_init, mamba_prefill_chunk,
                                    mamba_state_init)
    cfg = RECURRENT["mamba"]
    p = mamba_init(jax.random.key(3), cfg)
    B, S = 2, 13                        # S coprime with every chunk size
    x = jax.random.normal(jax.random.key(4), (B, S, cfg.d_model),
                          jnp.float32)
    y_ref, st_ref = mamba_prefill_chunk(p, x, cfg,
                                        mamba_state_init(cfg, B))
    page_size = 8
    for csize in (1, page_size, S - 1):
        st = mamba_state_init(cfg, B)
        ys = []
        for a in range(0, S, csize):
            y, st = mamba_prefill_chunk(p, x[:, a:a + csize], cfg, st)
            ys.append(y)
        y_split = jnp.concatenate(ys, axis=1)
        assert np.array_equal(np.asarray(y_split), np.asarray(y_ref)), \
            csize
        for k in ("h", "conv"):
            assert np.array_equal(np.asarray(st[k]),
                                  np.asarray(st_ref[k])), (csize, k)


def _poison_pools(eng, keep):
    """Set every KV pool position NOT in ``keep`` (a set of (page, off)
    pairs) to a huge finite value, in every layer.  Compressed formats
    carry parallel scale / residual pools; their positions poison too
    (int8 code pools saturate at +127, float scale pools get the huge
    value), so a mask leak would blow up regardless of format."""
    periods = {}
    for key, entry in eng.cache["periods"].items():
        entry = dict(entry)
        for name in ("k_pages", "v_pages", "k_scale", "v_scale",
                     "k_resid", "v_resid"):
            if name in entry:
                pool = np.asarray(entry[name]).copy()
                mask = np.ones(pool.shape[1:3], bool)   # (num_pages, page)
                for pg, off in keep:
                    mask[pg, off] = False
                pool[:, mask] = 127 if pool.dtype == np.int8 else 3e4
                entry[name] = jnp.asarray(pool)
        periods[key] = entry
    eng.cache = {"periods": periods}


@pytest.mark.parametrize("prefill_mode", ["chunked", "exact"])
def test_padded_tail_kv_positions_never_attend(prefill_mode):
    """The tail KV page holds non-prompt positions (zero-padded by the
    exact path's ``_scatter_prefill``, garbage-written by the chunked
    path), and padded table lanes point at the trash page.  None of
    them may EVER contribute to attention, for any plen % page_size:
    poison every non-prompt pool position with a huge finite value
    before AND after prefill — a mask leak would blow the logits up and
    flip tokens vs the oracle."""
    params = init_params(jax.random.key(0), CFG)
    page = 4
    for plen in (1, 3, 4, 6, 8):        # covers every residue mod 4
        # the second, shorter prompt pads its page table relative to the
        # first inside the shared prefill bucket, so the chunked gather
        # really reads (masked) trash-page rows during prefill
        prompts = [[(2 * plen + j) % 64 for j in range(plen)], [9, 10]]
        eng = ServeEngine(params, CFG, max_slots=2, max_len=16,
                          page_size=page, prefill_mode=prefill_mode)
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        _poison_pools(eng, keep=set())  # prefill must mask trash reads
        eng._admit()
        keep = {(r._table.pages[t // page], t % page)
                for r in eng.slots if r is not None
                for t in range(len(r.prompt))}
        _poison_pools(eng, keep)        # decode must mask the tail pad
        done = eng.run_to_completion()
        got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
        ref = sequential_generate(params, CFG, prompts,
                                  max_new_tokens=4, max_len=16)
        assert got == ref, (prefill_mode, plen)


@pytest.mark.parametrize("fmt,datapath", [("int8", "qat"),
                                          ("sc", "sc_int")])
def test_padded_tail_never_attends_compressed(fmt, datapath):
    """The poison theorem on the compressed pools: codes, scales AND
    residuals outside the positions a request owns must never reach
    attention — poisoned scales would multiply into huge dequantized
    K/V if any masked position leaked through."""
    params = init_params(jax.random.key(0), CFG)
    page = 4
    prompts = [[3, 1, 4, 1, 5, 9], [2, 6]]
    eng = ServeEngine(params, CFG, max_slots=2, max_len=16,
                      page_size=page, datapath=datapath, kv_format=fmt)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    _poison_pools(eng, keep=set())      # prefill must mask trash reads
    eng._admit()
    keep = {(r._table.pages[t // page], t % page)
            for r in eng.slots if r is not None
            for t in range(len(r.prompt))}
    _poison_pools(eng, keep)            # decode must mask the tail pad
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    want = sequential_generate(params, CFG, prompts, max_new_tokens=4,
                               max_len=16, datapath=datapath,
                               kv_format=fmt)
    assert got == want, fmt


def test_boundary_prompts_recurrent_match_sequential():
    """The prompt-length boundary on the recurrent chunked path: a
    prompt of max_len-1 tokens must emit exactly one token then stop,
    max_len-2 exactly two — `_check_done` after prefill must agree with
    sequential_generate's loop condition, same as the attention
    configs."""
    max_len = 16
    prompts = [list(range(1, max_len - 1)),        # max_len - 2 tokens
               list(range(1, max_len))]            # max_len - 1 tokens
    for arch in ("mamba", "rwkv6"):
        cfg = RECURRENT[arch]
        params = init_params(jax.random.key(0), cfg)
        got = _run_engine(params, cfg, prompts, max_new=8, max_slots=2,
                          max_len=max_len, page_size=4)
        ref = sequential_generate(params, cfg, prompts, max_new_tokens=8,
                                  max_len=max_len)
        assert got == ref, arch
        assert [len(g) for g in got] == [2, 1], arch


def test_recurrent_preemption_under_page_pressure():
    """Preempting a request on the recurrent path requeues it through
    the chunked prefill again (state rows rebuilt from zero); greedy
    decode is deterministic so tokens still match the oracle."""
    cfg = RECURRENT["jamba"]
    params = init_params(jax.random.key(0), cfg)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13]]
    got = _run_engine(params, cfg, prompts, max_new=12, max_slots=2,
                      max_len=24, page_size=8, num_pages=5)
    ref = sequential_generate(params, cfg, prompts, max_new_tokens=12,
                              max_len=24)
    assert got == ref


def test_sharded_serving_subprocess():
    """Tier-1 entry to the 8-device sharded suite
    (test_sharded_serving.py).  The forced host-device count must be
    set before jax initializes, so it needs a fresh interpreter; when
    this process already has 8 devices (the CI sharded job) the inner
    suite runs natively and this wrapper skips."""
    if jax.device_count() >= 8:
        pytest.skip("sharded suite runs natively in this process")
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.path.join(os.path.dirname(here), "src")
        + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join(here, "test_sharded_serving.py")],
        env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]


def test_decode_retraces_only_on_bucket_changes():
    """5 requests of mixed lengths through 2 slots crosses admissions,
    evictions and length growth constantly; the jitted decode must have
    compiled at most (slot buckets) x (page buckets) variants."""
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=4)
    for p in PROMPTS + [[5] * 9]:
        eng.submit(p, max_new_tokens=7)
    eng.run_to_completion()
    if hasattr(eng._decode, "_cache_size"):
        # slot buckets {1, 2} x page buckets {1, 2, 4} is the ceiling
        assert eng._decode._cache_size() <= 6

"""Selective interconnect (paper Fig 3b, Fig 7, Eq 1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bsn, coding, si


def brute_force_out_count(fn, c, in_max, out_bsl, alpha_in, alpha_out):
    v = alpha_in * (c - in_max / 2)
    y = fn(np.asarray([v]))[0]
    return int(np.clip(np.round(y / alpha_out + out_bsl / 2), 0, out_bsl))


@pytest.mark.parametrize("fn,alpha_in,alpha_out", [
    (si.relu_fn, 0.5, 0.5),
    (si.relu_fn, 0.25, 1.0),
    (si.identity_fn, 0.5, 0.5),
    (si.tanh_fn(2.0), 0.25, 0.125),
    (si.relu2_fn, 0.5, 1.0),
    (si.gelu_mono_fn, 0.25, 0.25),
    (si.silu_mono_fn, 0.25, 0.25),
])
def test_thresholds_realize_function_exactly(fn, alpha_in, alpha_out):
    """SI(c) == quantized target for EVERY input count (exactness claim)."""
    in_max, out_bsl = 64, 16
    t = si.si_thresholds(fn, in_max, out_bsl, alpha_in, alpha_out)
    cs = jnp.arange(in_max + 1)
    got = np.asarray(si.apply_si_counts(cs, jnp.asarray(t)))
    expect = np.array([brute_force_out_count(fn, int(c), in_max, out_bsl,
                                             alpha_in, alpha_out)
                       for c in range(in_max + 1)])
    np.testing.assert_array_equal(got, expect)


def test_bn_fused_relu():
    """Paper Eq 1 / Fig 7: BN parameters shift & space the thresholds."""
    gamma, beta = 1.5, 0.75
    fn = si.bn_relu_fn(gamma, beta)
    in_max, out_bsl = 128, 16
    t = si.si_thresholds(fn, in_max, out_bsl, alpha_in=0.125, alpha_out=0.25)
    cs = np.arange(in_max + 1)
    got = np.asarray(si.apply_si_counts(jnp.asarray(cs), jnp.asarray(t)))
    v = 0.125 * (cs - in_max / 2)
    y = np.where(v >= beta, gamma * (v - beta), 0.0)
    expect = np.clip(np.round(y / 0.25 + 8), 0, 16)
    np.testing.assert_array_equal(got, expect)
    # output is flat (== zero level) until the beta crossing
    zero_out = got[v < beta]
    assert np.all(zero_out == 8)        # 8 == zero point of 16-bit BSL


def test_bn_negative_gamma_rejected():
    with pytest.raises(ValueError):
        si.bn_relu_fn(-1.0, 0.0)


@given(st.integers(0, 40))
@settings(max_examples=20, deadline=None)
def test_bit_path_equals_count_path(seed):
    """Tapping sorted wires == counting thresholds (hardware == functional)."""
    rng = np.random.default_rng(seed)
    in_max, out_bsl = 32, 8
    t = si.si_thresholds(si.relu_fn, in_max, out_bsl, 0.5, 0.5)
    c = int(rng.integers(0, in_max + 1))
    sorted_bits = jnp.asarray([1] * c + [0] * (in_max - c), jnp.int8)
    got_bits = si.apply_si_bits(sorted_bits, jnp.asarray(t))
    assert coding.is_thermometer(np.asarray(got_bits)[None])[0]
    got_count = int(got_bits.sum())
    expect = int(si.apply_si_counts(jnp.asarray(c), jnp.asarray(t)))
    assert got_count == expect


def test_full_pipeline_bits():
    """multiplier -> BSN -> SI, fully bit-exact, equals float reference."""
    from repro.core import multiplier
    rng = np.random.default_rng(0)
    width, bsl = 16, 4
    alpha = 0.5
    a_q = rng.integers(-2, 3, width)
    w_q = rng.integers(-1, 2, width)
    a_bits = coding.encode_thermometer(jnp.asarray(a_q), bsl)
    prods = multiplier.ternary_scale_bits(jnp.asarray(w_q), a_bits)
    sorted_bits = bsn.exact_bsn_bits(prods)
    in_max = width * bsl
    out_bsl = 16
    t = si.si_thresholds(si.relu_fn, in_max, out_bsl,
                         alpha_in=alpha, alpha_out=alpha)
    out_bits = si.apply_si_bits(sorted_bits, jnp.asarray(t))
    got_val = alpha * (int(out_bits.sum()) - out_bsl / 2)
    exact = alpha * max(0.0, float((a_q * w_q).sum()))
    assert abs(got_val - exact) <= alpha / 2 + 1e-9


def test_monotonicity_enforced():
    with pytest.raises(ValueError):
        si.si_thresholds_from_counts(np.asarray([0, 2, 1, 3]), 4)


def test_constant_rails():
    """t_j = 0 -> constant 1; t_j = in_max+1 -> constant 0."""
    t = jnp.asarray([0, 2, 9])             # in_max = 8
    bits = jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], jnp.int8)
    out = np.asarray(si.apply_si_bits(bits, t))
    np.testing.assert_array_equal(out, [1, 1, 0])

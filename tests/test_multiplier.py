"""Ternary SC multiplier (paper Fig 3a) — gate-level vs functional."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import coding, multiplier


def test_truth_table_exhaustive():
    """All 9 ternary x ternary cases, gate-level == integer product."""
    for aq, wq in itertools.product([-1, 0, 1], repeat=2):
        a = coding.encode_thermometer(jnp.asarray(aq), 2)
        w = coding.encode_thermometer(jnp.asarray(wq), 2)
        p = multiplier.ternary_mul_bits(a, w)
        assert coding.is_thermometer(np.asarray(p)[None])[0], (aq, wq)
        assert int(coding.decode_thermometer(p)) == aq * wq, (aq, wq)


def test_batched_gate_level():
    key_vals = jnp.array([[-1, -1], [-1, 1], [0, 1], [1, 1], [1, -1]])
    a = coding.encode_thermometer(key_vals[:, 0], 2)
    w = coding.encode_thermometer(key_vals[:, 1], 2)
    p = multiplier.ternary_mul_bits(a, w)
    got = coding.decode_thermometer(p)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(key_vals[:, 0] * key_vals[:, 1]))


@given(st.integers(-1, 1), st.integers(-8, 8), st.sampled_from([4, 8, 16]))
@settings(max_examples=60, deadline=None)
def test_generalized_ternary_scale(wq, aq, bsl):
    """Ternary weight x L-bit activation == wiring ops (pass/zero/negate)."""
    half = bsl // 2
    aq = max(-half, min(half, aq))
    a_bits = coding.encode_thermometer(jnp.asarray(aq), bsl)
    p = multiplier.ternary_scale_bits(jnp.asarray(wq), a_bits)
    assert coding.is_thermometer(np.asarray(p)[None])[0]
    assert int(coding.decode_thermometer(p)) == wq * aq


def test_generalized_broadcast():
    wq = jnp.asarray([[1], [0], [-1]])                    # (3,1)
    aq = jnp.asarray([-2, 0, 2])                          # (3,)
    a_bits = coding.encode_thermometer(jnp.broadcast_to(aq, (3, 3)), 8)
    p = multiplier.ternary_scale_bits(wq, a_bits)
    got = np.asarray(coding.decode_thermometer(p))
    expect = np.asarray(wq) * np.asarray(aq)[None].repeat(3, 0).reshape(3, 3)
    # note: broadcasting is (3,1)x(3,3) -> rows scaled by w
    np.testing.assert_array_equal(got, np.asarray(wq) * np.asarray(aq))


def test_rejects_wrong_bsl():
    a = jnp.zeros((4,), jnp.int8)
    with pytest.raises(ValueError):
        multiplier.ternary_mul_bits(a, a)

"""Per-architecture smoke tests: reduced configs of the same family.

For each of the 10 assigned archs: instantiate a small-width/few-layer
copy, run one forward/train step on CPU, assert output shapes + no NaNs.
Full configs are exercised only via the dry-run (launch/dryrun.py).

Also validates the serving path: prefill + decode_step reproduce the
teacher-forced forward logits exactly (cache correctness for attention,
Mamba and RWKV state caching).
"""

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, make_dummy_batch, param_specs, prefill)

# reduced overrides per arch family; keeps every divisibility constraint
REDUCED = {
    "llava-next-34b": dict(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                           d_ff=128, vocab_size=131),
    "stablelm-1.6b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=96, vocab_size=131),
    "granite-3-2b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=131),
    "nemotron-4-15b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                           d_ff=192, vocab_size=131),
    "phi3-medium-14b": dict(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                            d_ff=128, vocab_size=131),
    "rwkv6-7b": dict(n_layers=2, d_model=64, d_ff=128, vocab_size=131,
                     n_heads=4, n_kv_heads=4, rwkv_head_dim=16),
    # capacity_factor >= E/k so no token ever drops: keeps train == serve
    # exactly (production configs use cf=1.25 with documented drop semantics)
    "jamba-1.5-large-398b": dict(n_layers=8, d_model=64, n_heads=4,
                                 n_kv_heads=2, d_ff=96, vocab_size=131,
                                 n_experts=4, n_experts_per_tok=2,
                                 mamba_d_state=8, moe_group_size=16,
                                 moe_capacity_factor=2.0),
    "qwen3-moe-235b-a22b": dict(n_layers=2, d_model=64, n_heads=4,
                                n_kv_heads=2, d_ff=48, vocab_size=131,
                                n_experts=8, n_experts_per_tok=2,
                                moe_group_size=16, moe_capacity_factor=4.0),
    "dbrx-132b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=96, vocab_size=131, n_experts=4,
                      n_experts_per_tok=2, moe_group_size=16,
                      moe_capacity_factor=2.0),
    "hubert-xlarge": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=67),
}

COMMON = dict(dtype="float32", attn_q_chunk=8, attn_kv_chunk=8,
              mamba_chunk=8, vocab_pad_multiple=32)

B, S = 2, 16
ALL_ARCHS = sorted(REDUCED)


def reduced(name):
    return get_arch(name).scaled(**REDUCED[name], **COMMON)


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jtu.tree_leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


def test_registry_complete():
    assert set(list_archs()) == set(ALL_ARCHS)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_spec_tree_matches(name):
    cfg = reduced(name)
    params = init_params(jax.random.key(0), cfg)
    specs = param_specs(cfg)
    assert jtu.tree_structure(params) == jtu.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    # every spec rank matches its param rank
    for (kp, leaf), (_, spec) in zip(
            jtu.tree_leaves_with_path(params),
            jtu.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))):
        assert len(spec) <= leaf.ndim, (kp, leaf.shape, spec)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_train_step_shapes_no_nans(name):
    cfg = reduced(name)
    params = init_params(jax.random.key(0), cfg)
    batch = make_dummy_batch(cfg, B, S, "train")
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert jnp.isfinite(loss), (name, metrics)
    assert _finite(grads), name
    logits, aux, _ = forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", [a for a in ALL_ARCHS
                                  if a != "hubert-xlarge"])
def test_prefill_decode_matches_forward(name):
    """Teacher-forced forward logits == prefill+decode logits (cache
    correctness across attention / mamba / rwkv / hybrid)."""
    cfg = reduced(name)
    params = init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(0)

    full = make_dummy_batch(cfg, B, S, "prefill")
    if "tokens" in full:
        toks = rng.integers(0, cfg.vocab_size, full["tokens"].shape)
        full["tokens"] = jnp.asarray(toks, jnp.int32)

    ref_logits, _, _ = forward(params, full, cfg)

    s_pre = S // 2
    prebatch = {k: v[:, :s_pre] if k != "patch_embeds" else v
                for k, v in full.items()}
    if cfg.frontend == "vision_stub":
        # keep all image tokens in prefill; split the text part
        n_img = full["patch_embeds"].shape[1]
        s_pre = max(n_img + 1, S // 2)
        prebatch = {"patch_embeds": full["patch_embeds"],
                    "tokens": full["tokens"][:, :s_pre - n_img]}
    logits, cache = prefill(params, prebatch, cfg)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(ref_logits[:, s_pre - 1]),
                               rtol=2e-4, atol=2e-4)

    # grow the KV cache to the full horizon before decoding
    def grow(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if names[-1] in ("k", "v") and leaf.ndim == 5:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, S - leaf.shape[2])
            return jnp.pad(leaf, pad)
        return leaf
    cache = {"pos": cache["pos"],
             "periods": jtu.tree_map_with_path(grow, cache["periods"])}

    if cfg.frontend == "vision_stub":
        next_tokens = full["tokens"][:, s_pre - full["patch_embeds"].shape[1]:]
    else:
        next_tokens = full["tokens"][:, s_pre:]
    for i in range(next_tokens.shape[1]):
        tok = next_tokens[:, i:i + 1]
        logits, cache = decode_step(params, cache, tok, cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref_logits[:, s_pre + i]),
            rtol=2e-4, atol=2e-4, err_msg=f"{name} step {i}")


def test_encoder_has_no_decode():
    cfg = reduced("hubert-xlarge")
    assert cfg.is_encoder
    params = init_params(jax.random.key(0), cfg)
    with pytest.raises(AssertionError):
        decode_step(params, init_cache(cfg, B, S),
                    jnp.zeros((B, 1), jnp.int32), cfg)


def test_encoder_bidirectional():
    """Changing a late frame must change an early frame's logits."""
    cfg = reduced("hubert-xlarge")
    params = init_params(jax.random.key(0), cfg)
    batch = make_dummy_batch(cfg, 1, S, "prefill")
    frames = jax.random.normal(jax.random.key(2), batch["frames"].shape,
                               jnp.float32)
    l1, _, _ = forward(params, {"frames": frames}, cfg)
    frames2 = frames.at[:, -1].add(1.0)
    l2, _, _ = forward(params, {"frames": frames2}, cfg)
    assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))


def test_causal_lm_is_causal():
    cfg = reduced("granite-3-2b")
    params = init_params(jax.random.key(0), cfg)
    t = jnp.zeros((1, S), jnp.int32)
    l1, _, _ = forward(params, {"tokens": t}, cfg)
    t2 = t.at[:, -1].set(5)
    l2, _, _ = forward(params, {"tokens": t2}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               rtol=1e-6)


def test_sc_quant_changes_forward():
    """sc_qat must actually quantize (differ from quant=none)."""
    cfg = reduced("granite-3-2b")
    cfg_off = cfg.scaled(quant=cfg.quant.with_mode("none"))
    # params trees differ (alpha scales); compare structurally instead
    p_on = init_params(jax.random.key(0), cfg)
    p_off = init_params(jax.random.key(0), cfg_off)
    assert len(jtu.tree_leaves(p_on)) > len(jtu.tree_leaves(p_off))
    batch = make_dummy_batch(cfg, 1, S, "prefill")
    l_on, _, _ = forward(p_on, batch, cfg)
    l_off, _, _ = forward(p_off, batch, cfg_off)
    assert not np.allclose(np.asarray(l_on), np.asarray(l_off))

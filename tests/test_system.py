"""End-to-end system behaviour: the full SC pipeline, float -> silicon.

The chain every other test file covers piecewise, asserted here in one
pass: QAT training improves the model; exporting to the integer datapath
(ternary weights + SI thresholds) preserves its behaviour; the integer
path equals the bit-level circuit simulation; and the Pallas kernel
computes the same integer path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bsn, coding, multiplier, si
from repro.core.quant import lsq_fake_quant
from repro.kernels import ops, ref


def test_end_to_end_sc_pipeline():
    rng = np.random.default_rng(0)
    din, dout, batch = 32, 8, 16
    act_bsl, out_bsl = 8, 16
    alpha_a, alpha_w = 0.25, 0.05

    # a "trained" layer: weights near-ternary, activations in range
    w = jnp.asarray(rng.normal(0, 0.05, (din, dout)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 0.5, (batch, din)), jnp.float32)

    # 1. QAT view (differentiable fake-quant)
    x_fq = lsq_fake_quant(x, jnp.asarray(alpha_a), -act_bsl // 2,
                          act_bsl // 2)
    w_fq = lsq_fake_quant(w, jnp.asarray(alpha_w), -1, 1)
    y_qat = x_fq @ w_fq

    # 2. integer datapath (what the silicon executes)
    x_q = coding.quantize_levels(x, alpha_a, act_bsl).astype(jnp.int8)
    w_int = np.clip(np.round(np.asarray(w) / alpha_w), -1, 1).astype(np.int8)
    sum_q = ref.ternary_matmul_ref(x_q, jnp.asarray(w_int))
    np.testing.assert_allclose(np.asarray(y_qat),
                               np.asarray(sum_q) * alpha_a * alpha_w,
                               rtol=1e-5, atol=1e-5)

    # 3. Pallas kernel == reference
    y_kernel = ops.ternary_matmul(x_q, jnp.asarray(w_int),
                                  min_flops_for_kernel=0,
                                  block_m=8, block_n=8, block_k=8)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(sum_q))

    # 4. bit-level circuit == integer path (one neuron, full bitstreams)
    bits = coding.encode_thermometer(x_q[0], act_bsl)
    prods = multiplier.ternary_scale_bits(jnp.asarray(w_int[:, 0]), bits)
    sorted_bits = bsn.exact_bsn_bits(prods)
    circuit = int(coding.counts_from_bits(sorted_bits)) - din * act_bsl // 2
    assert circuit == int(sum_q[0, 0])

    # 5. SI epilogue (BN-fused ReLU) applied on all three paths agrees
    t = si.si_thresholds(si.bn_relu_fn(1.5, 0.1), 2 * din * act_bsl // 2,
                         out_bsl, alpha_in=alpha_a * alpha_w,
                         alpha_out=alpha_a)
    t_q = jnp.asarray(t.astype(np.int64) - din * act_bsl // 2, jnp.int32)
    y_si_ref = ref.ternary_matmul_ref(x_q, jnp.asarray(w_int),
                                      jnp.tile(t_q, (dout, 1)))
    y_si_kernel = ops.ternary_matmul(x_q, jnp.asarray(w_int),
                                     jnp.tile(t_q, (dout, 1)),
                                     min_flops_for_kernel=0,
                                     block_m=8, block_n=8, block_k=8)
    np.testing.assert_array_equal(np.asarray(y_si_ref),
                                  np.asarray(y_si_kernel))
    si_bits = si.apply_si_bits(sorted_bits, jnp.asarray(t))
    assert int(si_bits.sum()) - out_bsl // 2 == int(y_si_ref[0, 0])


def test_sc_qat_lm_learns_end_to_end():
    """A reduced zoo LM under full SC-QAT beats its initial loss fast."""
    from repro.configs import get_arch
    from repro.data import SyntheticLM
    from repro.models import init_params
    from repro.train import build_train_step, init_train_state

    cfg = get_arch("granite-3-2b").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=64, vocab_pad_multiple=32, dtype="float32",
        attn_q_chunk=8)
    assert cfg.quant.mode == "sc_qat"
    from repro.optim import warmup_cosine
    ds = SyntheticLM(vocab_size=64, seq_len=16, seed=0)
    state = init_train_state(init_params(jax.random.key(0), cfg), cfg)
    step = jax.jit(build_train_step(
        cfg, lambda s: warmup_cosine(s, 3e-3, 10, 100)))
    losses = []
    for i in range(100):
        state, m = step(state, ds.batch(i, 8))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])

"""Structural jaxpr snapshots: the dot-product profile of the decode
step, per datapath.

Where the differential tests pin token VALUES, these pin the SHAPE of
the computation: which source functions contribute matmuls, at which
dtype kind.  A refactor that silently reroutes a projection through
float math (the MoE expert leak this PR fixed) changes this profile
even when tiny-scale tokens happen to agree.
"""

from collections import Counter
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.contracts import (decode_example_args, eqn_provenance,
                                      iter_eqns)
from repro.configs import get_arch
from repro.models import init_params
from repro.serving import ServeEngine

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)
CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
JAMBA = get_arch("jamba-1.5-large-398b").scaled(
    n_layers=8, **SCALE, mamba_d_state=8, n_experts=4,
    n_experts_per_tok=2, moe_capacity_factor=2.0)

_DOTS = ("dot_general", "conv_general_dilated")


def _dot_profile(cfg, datapath, kv_format="fp"):
    """Counter of (file:function, float|int) over the decode jaxpr's
    dot/conv equations."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, max_slots=4, max_len=64,
                      datapath=datapath, kv_format=kv_format)
    d_args = decode_example_args(eng)
    with eng._scope():
        jx = jax.make_jaxpr(partial(eng._decode_fn, do_sample=False))(
            eng.params, eng.cache, *d_args)
    prof = Counter()
    for eqn in iter_eqns(jx):
        if eqn.primitive.name not in _DOTS:
            continue
        dt = eqn.outvars[0].aval.dtype
        kind = "float" if jnp.issubdtype(dt, jnp.floating) else "int"
        prof[(eqn_provenance(eqn), kind)] += 1
    return prof


def test_granite_qat_decode_profile():
    """qat: every projection is a (fake-quantized) FLOAT dot through
    dense_apply — 4 per layer (qkv, attn-out, ffn up, ffn down) — plus
    the attention kernel's two f32 accumulations."""
    prof = _dot_profile(CFG, "qat")
    assert prof == Counter({
        ("models/common.py:dense_apply", "float"): 8,
        ("kernels/paged_attention.py:_accumulate", "float"): 2,
    }), prof


def test_granite_sc_int_decode_profile():
    """sc_int: the SAME 4-per-layer projection count, but every one an
    INTEGER dot from sc_linear_int — the only float dots left are the
    attention kernel's (allowlisted by design)."""
    prof = _dot_profile(CFG, "sc_int", kv_format="sc")
    assert prof == Counter({
        ("core/sc_layers.py:sc_linear_int", "int"): 8,
        ("kernels/paged_attention.py:_accumulate", "float"): 2,
    }), prof


def test_granite_sc_int_approx_decode_profile():
    """sc_int_approx: projections become BSN popcount accumulations
    (no dot primitives at all); only the attention kernel dots remain,
    and the jaxpr must actually contain sc_layers/bsn-attributed ops."""
    prof = _dot_profile(CFG, "sc_int_approx", kv_format="int8")
    assert prof == Counter({
        ("kernels/paged_attention.py:_accumulate", "float"): 2,
    }), prof
    # the BSN region must be present, not optimized away
    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=4, max_len=64,
                      datapath="sc_int_approx", kv_format="int8")
    d_args = decode_example_args(eng)
    with eng._scope():
        jx = jax.make_jaxpr(partial(eng._decode_fn, do_sample=False))(
            eng.params, eng.cache, *d_args)
    sc_eqns = sum(1 for e in iter_eqns(jx)
                  if eqn_provenance(e).startswith(("core/sc_layers.py",
                                                   "core/bsn.py")))
    assert sc_eqns > 0


def test_jamba_sc_int_expert_matmuls_are_integer():
    """The MoE regression this PR fixed: expert matmuls under sc_int
    run the int8 x ternary -> int32 path (12 integer dots: 3 expert
    einsums x 4 MoE layers), with NO float dot attributed to
    _expert_matmul.  moe_apply's float dots are the router gate +
    one-hot dispatch/combine einsums, outside the quantized datapath."""
    prof = _dot_profile(JAMBA, "sc_int")
    em = {k: v for k, v in prof.items()
          if k[0] == "models/moe.py:_expert_matmul"}
    assert em == {("models/moe.py:_expert_matmul", "int"): 12}, prof
    assert prof[("core/sc_layers.py:sc_linear_int", "int")] == 45, prof
    assert ("models/moe.py:moe_apply", "int") not in prof
    # full snapshot so ANY reroute shows up, not just the expert one
    assert prof == Counter({
        ("core/sc_layers.py:sc_linear_int", "int"): 45,
        ("models/moe.py:_expert_matmul", "int"): 12,
        ("models/moe.py:moe_apply", "float"): 20,
        ("models/mamba.py:mamba_decode", "float"): 7,
        ("kernels/paged_attention.py:_accumulate", "float"): 2,
    }), prof

"""Cross-datapath speculative decoding: proven distribution-preserving.

The claim under test is the strongest one speculative decoding can
make: with the drafter and verifier riding the SAME (seed, position)
Gumbel streams, the emitted tokens are *always* target draws, so
spec-on equals spec-off **token for token** — bit-reproducibility, not
just distributional equality.  Three layers pin it:

1. Property layer — the acceptance rule in isolation.  The prefix law
   of ``speculative_accept`` (hypothesis / the conftest fallback), and
   a Monte-Carlo chi-square check that coupled emission leaves the
   target marginal untouched while draft==target accepts everything.
2. Differential layer — the engine matrix.  spec-on == spec-off ==
   ``sequential_generate`` (greedy exact, seeded-sampled bit-identical)
   across target datapaths x mixer families, through preemption
   mid-draft and the max_len window fallback.  The mesh third of the
   family lives in tests/test_sharded_serving.py.
3. Logprobs layer — ``token_logprobs`` scores against the exact
   distribution each lane drew from, the engine surfaces it without
   perturbing tokens, and ``logprobs=0`` (the default) compiles the
   historical step — no sampler/sort compute in the jaxpr, pinned via
   the PR 8 dot-profile machinery.
"""

from collections import Counter
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.contracts import (decode_example_args, eqn_provenance,
                                      iter_eqns)
from repro.configs import LayerSpec, get_arch
from repro.models import forward, init_params
from repro.serving import (EngineConfig, SamplingParams, ServeEngine,
                           sequential_generate)
from repro.serving.sampling import (pack_sampling, sample_tokens,
                                    speculative_accept, token_logprobs)

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)
CFGS = {
    "attn": get_arch("granite-3-2b").scaled(n_layers=2, **SCALE),
    "mamba": get_arch("jamba-1.5-large-398b").scaled(
        period=(LayerSpec("mamba", "dense"),), n_layers=2, **SCALE,
        mamba_d_state=8),
    "rwkv6": get_arch("rwkv6-7b").scaled(
        n_layers=2, **{**SCALE, "n_kv_heads": 4}),
    "jamba": get_arch("jamba-1.5-large-398b").scaled(
        n_layers=8, **SCALE, mamba_d_state=8, n_experts=4,
        n_experts_per_tok=2, moe_capacity_factor=2.0),
}
PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
SAMPLED = [SamplingParams(temperature=0.9, top_k=8, seed=42 + i)
           for i in range(len(PROMPTS))]


@lru_cache(maxsize=None)
def _params(arch: str):
    return init_params(jax.random.key(0), CFGS[arch])


_RUNS: dict = {}


def _tokens(arch, datapath, spec, sampling=None, max_new=8, **kw):
    """Run the engine over PROMPTS; return ([generated...], engine).
    Memoized on the full call signature: several tests compare against
    the same spec-off baseline, and each engine build costs seconds of
    XLA compiles at tiny scale."""
    key = (arch, datapath, spec, tuple(sampling) if sampling else None,
           max_new, tuple(sorted(kw.items())))
    if key in _RUNS:
        return _RUNS[key]
    cfg = CFGS[arch]
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    eng = ServeEngine(_params(arch), cfg, datapath=datapath,
                      spec_decode=spec, draft_len=3, **kw)
    sps = sampling if sampling is not None else [None] * len(PROMPTS)
    for p, sp in zip(PROMPTS, sps):
        eng.submit(p, max_new_tokens=max_new, sampling=sp)
    done = eng.run_to_completion()
    assert len(done) == len(PROMPTS)
    out = [r.generated for r in sorted(done, key=lambda r: r.rid)], eng
    _RUNS[key] = out
    return out


# ---------------------------------------------------------------------------
# 1. the acceptance rule in isolation
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=8),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=40, deadline=None)
def test_accept_prefix_law(k, i, seed):
    """m is the first index where draft and target disagree (k if they
    never do) — mismatches AFTER the first must not matter."""
    rng = np.random.default_rng(seed)
    draft = rng.integers(0, 64, size=(1, k)).astype(np.int32)
    target = draft.copy()
    if i < k:
        target[0, i] = (target[0, i] + 1 + rng.integers(0, 62)) % 64
        # scramble everything past the first divergence: irrelevant
        target[0, i + 1:] = rng.integers(0, 64, size=k - i - 1)
    m = int(speculative_accept(jnp.asarray(draft), jnp.asarray(target))[0])
    assert m == min(i, k)


def test_accept_is_per_lane():
    draft = jnp.asarray([[5, 6, 7], [5, 6, 7], [5, 6, 7]], jnp.int32)
    target = jnp.asarray([[5, 6, 7], [5, 9, 7], [9, 6, 7]], jnp.int32)
    assert speculative_accept(draft, target).tolist() == [3, 1, 0]


def test_coupled_emission_preserves_target_marginal():
    """The Monte-Carlo heart of the scheme.  At one position, draft and
    target draws share Gumbel noise g: d = argmax(ld + g), tau =
    argmax(lt + g).  The emitted token is ALWAYS tau (an accepted draft
    IS tau; a rejected one is replaced by tau), so its marginal is
    exactly softmax(lt) regardless of how bad the drafter is —
    chi-square tested over many independent seed streams.  And when the
    drafter equals the target, the coupling makes d == tau ALWAYS:
    acceptance is 1.0, not merely high."""
    V, N = 8, 4096
    rng = np.random.default_rng(7)
    lt = rng.normal(size=V).astype(np.float32) * 1.5
    ld = rng.normal(size=V).astype(np.float32) * 1.5   # unrelated drafter
    samp = pack_sampling([SamplingParams(temperature=1.0, seed=s)
                          for s in range(N)])
    pos = jnp.full((N,), 11, jnp.int32)
    tile = lambda row: jnp.broadcast_to(jnp.asarray(row), (N, V))
    tau = np.asarray(sample_tokens(tile(lt), pos, samp, V))
    d = np.asarray(sample_tokens(tile(ld), pos, samp, V))

    # (a) perfect drafter => perfect acceptance (coupling, not luck)
    assert np.array_equal(
        np.asarray(sample_tokens(tile(lt), pos, samp, V)), tau)

    # (b) the emitted marginal is the target softmax: chi-square over V
    # bins, dof = V-1 = 7; 24.32 is the 99.9% point — the draw is
    # seed-deterministic, so this either always passes or flags a real
    # distribution shift, it cannot flake.
    p = np.exp(lt - lt.max());  p /= p.sum()
    obs = np.bincount(tau, minlength=V).astype(np.float64)
    chi2 = float(((obs - N * p) ** 2 / (N * p)).sum())
    assert chi2 < 24.32, (chi2, obs.tolist(), (N * p).tolist())

    # (c) the coupling is monotone: acceptance is far above the
    # independent-draws rate sum_v p_d(v) p_t(v), which for these two
    # rows is ~0.2 — shared noise concentrates agreement.
    pd = np.exp(ld - ld.max());  pd /= pd.sum()
    independent = float((pd * p).sum())
    coupled = float((d == tau).mean())
    assert coupled > independent + 0.1, (coupled, independent)


# ---------------------------------------------------------------------------
# 2. the engine differential matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("datapath", ["qat", "sc_int"])
@pytest.mark.parametrize("arch", ["attn", "mamba", "rwkv6"])
def test_spec_greedy_matches_plain_and_sequential(arch, datapath):
    """Greedy: spec-on emits exactly the spec-off tokens, which are
    exactly the per-request sequential oracle's tokens — across both
    target datapaths and all three mixer families."""
    spec, eng = _tokens(arch, datapath, spec=True)
    plain, _ = _tokens(arch, datapath, spec=False)
    assert spec == plain
    if arch == "attn":
        # plain == sequential is already pinned per-arch by
        # test_paged_kv / test_sampling; close the triangle once here
        ref = sequential_generate(_params(arch), CFGS[arch], PROMPTS,
                                  max_new_tokens=8, datapath=datapath)
        assert spec == ref
    st = eng.spec_stats
    assert st["rounds"] >= 1 and st["emitted_tokens"] >= st["rounds"]
    assert st["accepted_tokens"] <= st["draft_tokens"]
    assert st["tokens_per_round"] >= 1.0


@pytest.mark.parametrize("datapath", ["qat", "sc_int"])
def test_spec_sampled_bit_identical(datapath):
    """Seeded sampling: the coupled streams make spec-on == spec-off
    bit-identical (same tokens, not just same distribution)."""
    spec, _ = _tokens("attn", datapath, spec=True, sampling=SAMPLED)
    plain, _ = _tokens("attn", datapath, spec=False, sampling=SAMPLED)
    assert spec == plain
    ref = sequential_generate(_params("attn"), CFGS["attn"], PROMPTS,
                              max_new_tokens=8, datapath=datapath,
                              sampling=SAMPLED)
    assert spec == ref


def test_spec_sampled_hybrid_jamba():
    """The 8-layer hybrid (mamba + attention + MoE + cmix) exercises
    every verify path — attention window scoring AND recurrent
    state-snapshot rollback — in one model."""
    spec, _ = _tokens("jamba", "sc_int", spec=True, sampling=SAMPLED,
                      max_new=6)
    plain, _ = _tokens("jamba", "sc_int", spec=False, sampling=SAMPLED,
                       max_new=6)
    assert spec == plain


def test_spec_preemption_mid_draft():
    """Under pool pressure a spec round may be impossible (growing the
    draft window would evict work): the engine must fall back to plain
    decode ticks, never preempt FOR speculation, and still emit the
    spec-off tokens exactly."""
    prompts = PROMPTS + [[10, 11, 12, 13, 14]]
    kw = dict(max_slots=4, max_len=64, page_size=8, num_pages=9)
    cfg = CFGS["attn"]
    outs = []
    for spec in (True, False):
        eng = ServeEngine(_params("attn"), cfg, datapath="qat",
                          spec_decode=spec, draft_len=3, **kw)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        done = eng.run_to_completion()
        assert len(done) == len(prompts)
        outs.append([r.generated for r in sorted(done,
                                                 key=lambda r: r.rid)])
    assert outs[0] == outs[1]


def test_spec_window_fallback_near_max_len():
    """Lanes within draft_len+1 of max_len cannot host a window; the
    round degrades to plain decode and truncation lengths match the
    spec-off engine exactly."""
    spec, _ = _tokens("attn", "qat", spec=True, max_new=32, max_len=16)
    plain, _ = _tokens("attn", "qat", spec=False, max_new=32, max_len=16)
    assert spec == plain
    assert [len(g) for g in spec] == [16 - len(p) for p in PROMPTS]


def test_draft_equals_target_accepts_everything():
    """Mechanism proof: point the drafter at the target datapath and
    the shared-Gumbel coupling accepts EVERY draft (rate exactly 1.0),
    with tokens still identical to spec-off.  Real sc_int_approx
    drafters at random-init tiny scale accept ~nothing — which the
    differential above shows is still output-preserving."""
    cfg = CFGS["attn"]
    eng = ServeEngine(_params("attn"), cfg, datapath="qat",
                      spec_decode=True, draft_len=3, max_slots=4,
                      max_len=64, page_size=8)
    eng.cfg_draft = eng.cfg            # perfect drafter
    for p, sp in zip(PROMPTS, SAMPLED):
        eng.submit(p, max_new_tokens=8, sampling=sp)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    plain, _ = _tokens("attn", "qat", spec=False, sampling=SAMPLED)
    assert got == plain
    st = eng.spec_stats
    assert st["acceptance_rate"] == 1.0, st
    # prefill emits token 1; the 7 remaining tokens per lane take
    # ceil(7 / (k+1)) = 2 verify rounds instead of 7 decode ticks —
    # the whole speedup thesis in one integer
    assert st["rounds"] == 2, st
    assert st["emitted_tokens"] == 7 * len(PROMPTS), st


# ---------------------------------------------------------------------------
# 3. logprobs
# ---------------------------------------------------------------------------

def test_token_logprobs_scores_the_drawn_distribution():
    """Greedy lanes score against log-softmax of the RAW logits;
    sampled lanes against log-softmax of the FILTERED logits (the
    distribution the draw actually came from)."""
    rng = np.random.default_rng(3)
    V = 16
    logits = jnp.asarray(rng.normal(size=(2, V)).astype(np.float32))
    samp = pack_sampling([SamplingParams(),                     # greedy
                          SamplingParams(temperature=0.7, top_k=4,
                                         seed=1)])
    toks = jnp.asarray([int(np.argmax(np.asarray(logits[0]))), 2],
                       jnp.int32)
    chosen, top_ids, top_lp = token_logprobs(logits, toks, samp, V, k=V)

    raw = jax.nn.log_softmax(logits[0])
    assert float(chosen[0]) == pytest.approx(float(raw[toks[0]]), abs=1e-6)
    assert int(top_ids[0, 0]) == int(toks[0])          # top-1 is argmax
    # the full-width top list is a proper distribution (sums to one)
    assert float(jnp.exp(top_lp[0]).sum()) == pytest.approx(1.0, abs=1e-5)

    # sampled lane: exactly top_k=4 finite entries, -inf outside, and
    # they renormalize over the kept set at temperature 0.7
    kept = np.asarray(jnp.isfinite(top_lp[1])).sum()
    assert kept == 4
    assert float(jnp.exp(top_lp[1]).sum()) == pytest.approx(1.0, abs=1e-5)
    scaled = jax.nn.log_softmax(
        jnp.sort(logits[1])[-4:][::-1] / 0.7)
    assert np.allclose(np.asarray(jnp.sort(top_lp[1])[-4:][::-1]),
                       np.asarray(scaled), atol=1e-5)


def test_engine_logprobs_match_dense_forward():
    """Greedy engine logprobs equal the log-softmax of a dense
    (un-paged, un-bucketed) forward pass over the final sequence — the
    paged step's logits really are the model's logits."""
    cfg = CFGS["attn"]
    eng = ServeEngine(_params("attn"), cfg, datapath="qat", max_slots=4,
                      max_len=64, page_size=8)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=5,
                   sampling=SamplingParams(logprobs=4))
    done = sorted(eng.run_to_completion(), key=lambda r: r.rid)
    for r, prompt in zip(done, PROMPTS):
        assert len(r.logprobs) == len(r.generated)
        ids = jnp.asarray([prompt + r.generated], jnp.int32)
        logits, _, _ = forward(_params("attn"), {"tokens": ids}, cfg,
                               mode="prefill")
        lp = jax.nn.log_softmax(
            logits[0, :, :cfg.vocab_size].astype(jnp.float32), axis=-1)
        for i, (tok, rec) in enumerate(zip(r.generated, r.logprobs)):
            want = float(lp[len(prompt) - 1 + i, tok])
            assert rec["logprob"] == pytest.approx(want, abs=1e-4)
            assert len(rec["top"]) == 4
            assert rec["top"][0][0] == tok      # greedy: top-1 == draw


def test_spec_logprobs_equal_plain_logprobs():
    """Logprobs ride the verify step unchanged: spec-on surfaces the
    same records as spec-off, for greedy and seeded-sampled lanes."""
    sps = [SamplingParams(logprobs=2),
           SamplingParams(temperature=0.9, top_k=8, seed=5, logprobs=2),
           SamplingParams(logprobs=2)]
    runs = []
    for spec in (True, False):
        eng = ServeEngine(_params("attn"), CFGS["attn"], datapath="qat",
                          spec_decode=spec, draft_len=3, max_slots=4,
                          max_len=64, page_size=8)
        for p, sp in zip(PROMPTS, sps):
            eng.submit(p, max_new_tokens=6, sampling=sp)
        done = sorted(eng.run_to_completion(), key=lambda r: r.rid)
        runs.append([(r.generated, r.logprobs) for r in done])
    for (g_on, lp_on), (g_off, lp_off) in zip(*runs):
        assert g_on == g_off
        assert len(lp_on) == len(lp_off)
        for a, b in zip(lp_on, lp_off):
            assert a["logprob"] == pytest.approx(b["logprob"], abs=1e-6)
            assert [t for t, _ in a["top"]] == [t for t, _ in b["top"]]


def test_logprobs_off_compiles_the_historical_step():
    """lp_k=0 (nobody asked) must trace the byte-for-byte historical
    decode step: no top_k/sort primitives from token_logprobs, and the
    dot-profile snapshot from test_datapath_structure unchanged.  lp_k>0
    is the only thing that buys the extra compute."""
    cfg = CFGS["attn"]
    eng = ServeEngine(_params("attn"), cfg, max_slots=4, max_len=64)
    d_args = decode_example_args(eng)

    def profile(lp_k):
        with eng._scope():
            jx = jax.make_jaxpr(partial(eng._decode_fn, do_sample=False,
                                        lp_k=lp_k))(
                eng.params, eng.cache, *d_args)
        prims = Counter(e.primitive.name for e in iter_eqns(jx))
        dots = Counter()
        for e in iter_eqns(jx):
            if e.primitive.name in ("dot_general", "conv_general_dilated"):
                kind = ("float" if jnp.issubdtype(e.outvars[0].aval.dtype,
                                                  jnp.floating) else "int")
                dots[(eqn_provenance(e), kind)] += 1
        return prims, dots

    prims0, dots0 = profile(0)
    assert prims0["top_k"] == 0 and prims0["sort"] == 0, prims0
    assert dots0 == Counter({
        ("models/common.py:dense_apply", "float"): 8,
        ("kernels/paged_attention.py:_accumulate", "float"): 2,
    }), dots0
    prims4, _ = profile(4)
    assert prims4["top_k"] >= 1, prims4   # the sampler compute is real


def test_logprobs_zero_request_records_nothing():
    eng = ServeEngine(_params("attn"), CFGS["attn"], datapath="qat",
                      spec_decode=True, draft_len=3, max_slots=4,
                      max_len=64, page_size=8)
    for p in PROMPTS:        # default SamplingParams: logprobs=0
        eng.submit(p, max_new_tokens=4)
    done = eng.run_to_completion()
    assert done and all(not r.logprobs for r in done)


# ---------------------------------------------------------------------------
# 4. configuration surface
# ---------------------------------------------------------------------------

def test_sampling_params_rejects_negative_logprobs():
    with pytest.raises(ValueError, match="logprobs"):
        SamplingParams(logprobs=-1)


@pytest.mark.parametrize("bad", [0, -1, -7])
def test_config_rejects_nonpositive_draft_len(bad):
    with pytest.raises(ValueError, match="draft_len"):
        EngineConfig(spec_decode=True, draft_len=bad).validate()
    with pytest.raises(ValueError, match="draft_len"):
        # the rule holds even with speculation off: the knob must
        # never sit in an unusable state waiting to explode later
        EngineConfig(draft_len=bad).validate()
    with pytest.raises(ValueError, match="draft_len"):
        ServeEngine(_params("attn"), CFGS["attn"], draft_len=bad)


@pytest.mark.parametrize("datapath,ok", [("qat", True), ("sc_int", True),
                                         ("sc_int_approx", False)])
def test_config_spec_decode_target_matrix(datapath, ok):
    """spec_decode with an sc_int_approx target is drafter == verifier:
    a no-op that doubles compute — rejected.  Every other combination
    validates, and the plain-kwargs shim routes through the same rule."""
    cfg = EngineConfig(datapath=datapath, spec_decode=True)
    if ok:
        assert cfg.validate() is cfg
        assert EngineConfig(datapath=datapath).validate()
    else:
        with pytest.raises(ValueError, match="sc_int_approx"):
            cfg.validate()
        # speculation OFF on the approx datapath stays legal
        assert EngineConfig(datapath=datapath).validate()
        with pytest.raises(ValueError, match="sc_int_approx"):
            ServeEngine(_params("attn"), CFGS["attn"], datapath=datapath,
                        spec_decode=True)


def test_shim_kwargs_reach_the_engine():
    eng = ServeEngine(_params("attn"), CFGS["attn"], spec_decode=True,
                      draft_len=2, max_slots=2, max_len=32)
    assert eng.spec_decode is True and eng.draft_len == 2
    assert eng.config.spec_decode is True and eng.config.draft_len == 2

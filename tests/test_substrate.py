"""Substrate: optimizer, train loop + checkpoint/restart, data, serving."""

import os

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import SyntheticLM, host_batch
from repro.distributed.compression import (compress_decompress,
                                           init_error_state)
from repro.models import init_params
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    warmup_cosine
from repro.train import build_train_step, init_train_state
from repro.train.loop import run_training
from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, wait_for_saves)

CFG = get_arch("granite-3-2b").scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=64, vocab_pad_multiple=32, dtype="float32",
    attn_q_chunk=8)
# unquantized twin: substrate-linearity tests (grad accum ==
# single batch) are exact only without LSQ round() boundaries
CFG_NOQ = CFG.scaled(quant=CFG.quant.with_mode("none"))


def _state(seed=0, cfg=CFG, **kw):
    params = init_params(jax.random.key(seed), cfg)
    return init_train_state(params, cfg, **kw)


def _ds():
    return SyntheticLM(vocab_size=CFG.vocab_size, seq_len=16, seed=3)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_loss_on_quadratic():
    params = {"w": jnp.asarray([2.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(g, opt, params, 0.05, weight_decay=0.0)
    assert float(jnp.sum(params["w"] ** 2)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 100 * np.sqrt(10), rtol=1e-5)
    from repro.optim import global_norm
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, 1e-3, 10, 100)) == 0.0
    assert float(warmup_cosine(10, 1e-3, 10, 100)) == pytest.approx(1e-3)
    assert float(warmup_cosine(100, 1e-3, 10, 100)) == pytest.approx(1e-4)


# ---------------------------------------------------------------------------
# train step: loss goes down on the synthetic language
# ---------------------------------------------------------------------------

def test_train_step_learns():
    """SC-QAT path learns (the d=64 toy plateaus well above the floor;
    examples/train_qat.py shows near-floor convergence at d=256).

    The improvement threshold is a *measured margin*, not a magic
    constant: everything here is pinned (init seed, data seed, CPU f32
    math), and across init seeds {0, 1, 2} on the pinned jax stack the
    100-step run closes 17..20% of the gap between the initial loss and
    the language's entropy floor (5-step window means).  Asserting >= 8%
    keeps >2x headroom over the weakest measured seed while still
    catching a dead optimizer (which closes ~0%).
    """
    ds = _ds()
    step_fn = jax.jit(build_train_step(
        CFG, lambda s: warmup_cosine(s, 3e-3, 10, 100)))
    state = _state()
    losses = []
    for i in range(100):
        state, metrics = step_fn(state, ds.batch(i, 8))
        losses.append(float(metrics["loss"]))
    first = float(np.mean(losses[:5]))
    last = float(np.mean(losses[-5:]))
    # entropy floor of the Markov language is log(branching)
    floor = float(np.log(ds.branching))
    closed = (first - last) / max(first - floor, 1e-9)
    assert closed > 0.08, (first, last, floor, closed)
    assert last > 0.9 * floor


def test_grad_accum_matches_single_batch():
    # quantization-free twin: LSQ round() boundaries make post-update
    # params one-quant-step sensitive to 1e-7 grad reorderings
    ds = _ds()
    batch = ds.batch(0, 8)
    s1 = _state(7, cfg=CFG_NOQ)
    s2 = _state(7, cfg=CFG_NOQ)
    f1 = jax.jit(build_train_step(CFG_NOQ, lambda s: 1e-3))
    f4 = jax.jit(build_train_step(CFG_NOQ, lambda s: 1e-3, grad_accum=4))
    s1, m1 = f1(s1, batch)
    s2, m2 = f4(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    l1 = jtu.tree_leaves(s1.params)
    l2 = jtu.tree_leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_grad_compression_error_feedback():
    g = {"w": jax.random.normal(jax.random.key(0), (64, 64))}
    e = init_error_state(g)
    g2, e2 = compress_decompress(g, e)
    # int8 quantization error is bounded by scale/2
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(g2["w"] - g["w"]))) <= scale * 0.51
    # error feedback: residual is exactly the quantization error
    np.testing.assert_allclose(np.asarray(e2["w"]),
                               np.asarray(g["w"] - g2["w"]), atol=1e-6)
    # compressed training still learns (unquantized twin — isolates the
    # compression effect from LSQ plateau noise)
    ds = _ds()
    step_fn = jax.jit(build_train_step(CFG_NOQ, lambda s: 3e-3,
                                       grad_compress=True))
    state = _state(1, cfg=CFG_NOQ, grad_compress=True)
    first = last = None
    for i in range(50):
        state, m = step_fn(state, ds.batch(i, 8))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.3, (first, last)


# ---------------------------------------------------------------------------
# checkpoint: atomic save, elastic restore, loop restart
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 5, state, async_=False)
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), 5, jax.tree.map(
        jnp.zeros_like, state))
    for a, b in zip(jtu.tree_leaves(state), jtu.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_partial(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 3, state, async_=False)
    os.makedirs(tmp_path / "step_9.tmp")          # simulated dead writer
    os.makedirs(tmp_path / "step_7")              # no manifest -> invalid
    assert latest_step(str(tmp_path)) == 3


def test_loop_restart_resumes_deterministically(tmp_path):
    """Train 6 steps straight vs 3 + crash + resume: identical params."""
    ds = _ds()
    mk = lambda: jax.jit(build_train_step(CFG, lambda s: 1e-3))
    batch_fn = lambda step: ds.batch(step, 4)

    sA, _ = run_training(mk(), _state(5), batch_fn, 6, ckpt_dir=None,
                         log_every=100, log_fn=lambda *_: None)

    ck = str(tmp_path / "run")
    os.makedirs(ck)
    run_training(mk(), _state(5), batch_fn, 3, ckpt_dir=ck, ckpt_every=3,
                 log_every=100, log_fn=lambda *_: None)
    wait_for_saves()
    assert latest_step(ck) == 3
    # "new process": fresh state, loop restores from step 3 and continues
    sB, _ = run_training(mk(), _state(5), batch_fn, 6, ckpt_dir=ck,
                         ckpt_every=100, log_every=100,
                         log_fn=lambda *_: None)
    for a, b in zip(jtu.tree_leaves(sA.params), jtu.tree_leaves(sB.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    ds = _ds()
    b1 = ds.batch(7, 8)
    b2 = ds.batch(7, 8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # host sharding tiles the global batch
    h0 = host_batch(ds, 7, 8, host_id=0, n_hosts=2)
    h1 = host_batch(ds, 7, 8, host_id=1, n_hosts=2)
    np.testing.assert_array_equal(np.asarray(h0["tokens"]),
                                  np.asarray(b1["tokens"][:4]))
    np.testing.assert_array_equal(np.asarray(h1["tokens"]),
                                  np.asarray(b1["tokens"][4:]))
    # targets are next-token
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serving_continuous_batching_matches_forward():
    from repro.models import forward
    from repro.serving import ServeEngine
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32)
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    done = eng.run_to_completion()
    assert len(done) == 3 and all(len(r.generated) == 5 for r in done)

    # greedy engine output == teacher-forced argmax rollout
    for r, prompt in zip(sorted(done, key=lambda r: r.rid), prompts):
        toks = list(prompt)
        for t in range(5):
            logits, _, _ = forward(params, {
                "tokens": jnp.asarray(toks, jnp.int32)[None]}, CFG)
            nxt = int(jnp.argmax(logits[0, -1, :CFG.vocab_size]))
            assert nxt == r.generated[t], (r.rid, t)
            toks.append(nxt)

"""Thermometer coding (paper Table II) — exact semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import coding


def bits_to_str(bits):
    return "".join(str(int(b)) for b in np.asarray(bits))


@pytest.mark.parametrize("bsl", [2, 4])
def test_table_ii_exact(bsl):
    """The coding table printed in the paper, asserted verbatim."""
    for level, expect in coding.THERMOMETER_TABLE[bsl].items():
        got = coding.encode_thermometer(jnp.asarray(level), bsl)
        assert bits_to_str(got) == expect, (bsl, level)


@pytest.mark.parametrize("bsl", [2, 4, 8, 16, 64])
def test_roundtrip_all_levels(bsl):
    half = bsl // 2
    levels = jnp.arange(-half, half + 1)
    bits = coding.encode_thermometer(levels, bsl)
    assert bits.shape == (bsl + 1, bsl)
    assert np.all(coding.is_thermometer(bits))
    back = coding.decode_thermometer(bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(levels))


def test_out_of_range_saturates():
    bits = coding.encode_thermometer(jnp.asarray([-99, 99]), 8)
    np.testing.assert_array_equal(
        np.asarray(coding.decode_thermometer(bits)), [-4, 4])


@given(st.integers(-8, 8))
@settings(max_examples=25, deadline=None)
def test_negate_is_value_negation(level):
    bits = coding.encode_thermometer(jnp.asarray(level), 16)
    neg = coding.negate_bits(bits)
    assert coding.is_thermometer(np.asarray(neg)[None])[0]
    assert int(coding.decode_thermometer(neg)) == -level


def test_zero_code():
    z = coding.zero_code(8)
    assert bits_to_str(z) == "11110000"
    assert int(coding.decode_thermometer(z)) == 0


@given(st.floats(-3, 3, allow_nan=False), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=50, deadline=None)
def test_quantize_dequantize_error_bound(x, bsl):
    alpha = 0.25
    q = coding.quantize_levels(jnp.asarray(x), alpha, bsl)
    half = bsl // 2
    assert -half <= int(q) <= half
    deq = float(coding.dequantize_levels(q, alpha))
    if abs(x) <= alpha * half:            # in range: half-step error bound
        assert abs(deq - x) <= alpha / 2 + 1e-6
    else:                                  # saturated
        assert abs(deq) == alpha * half


def test_odd_bsl_rejected():
    with pytest.raises(ValueError):
        coding.check_bsl(3)
    with pytest.raises(ValueError):
        coding.check_bsl(0)

"""Hot-path contract gate: positive battery + injection tests.

Every audit in ``repro.analysis.contracts`` must (a) pass clean on the
real engine and (b) catch a deliberately injected violation with a
message naming the right pass and source location — a pass without an
injection test is assumed vacuous (analysis/README.md).
"""

import os
import subprocess
import sys
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.contracts import (audit_donation, audit_dtype_purity,
                                      audit_engine_retrace,
                                      audit_host_boundary, audit_sharding,
                                      decode_example_args,
                                      run_engine_contracts)
from repro.configs import LayerSpec, get_arch
from repro.launch.mesh import make_serving_mesh, serving_rules
from repro.models import init_params
from repro.serving import ServeEngine, sequential_generate
from repro.serving import engine as engine_mod

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)
CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
JAMBA = get_arch("jamba-1.5-large-398b").scaled(
    n_layers=8, **SCALE, mamba_d_state=8, n_experts=4,
    n_experts_per_tok=2, moe_capacity_factor=2.0)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _engine(params, cfg=CFG, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 64)
    return ServeEngine(params, cfg, **kw)


def _messages(result):
    return " | ".join(v.message for v in result.violations)


# ---------------------------------------------------------------------------
# positive battery
# ---------------------------------------------------------------------------

def test_engine_contracts_clean(params):
    """The full static battery is clean on the quantized SC engine."""
    eng = _engine(params, datapath="sc_int", kv_format="sc")
    results = run_engine_contracts(eng, "granite/sc_int/sc")
    bad = [v for r in results for v in r.violations]
    assert not bad, [v.to_dict() for v in bad]
    # the exact-prefill donation exemption is recorded, not hidden
    assert any("exempt" in n.lower() or "exact" in n.lower()
               for r in results for n in r.notes)


def test_retrace_audit_clean(params):
    eng = _engine(params)
    r = audit_engine_retrace(eng, [[1, 2, 3], [4, 5, 6, 7]],
                             "granite/live")
    assert r.ok, _messages(r)


# ---------------------------------------------------------------------------
# injections — each breaks ONE invariant and must be caught by name
# ---------------------------------------------------------------------------

def test_donation_injection_caught(params):
    """Re-jitting decode WITHOUT donate_argnums must fail the donation
    audit: the pool leaves lose their buffer aliasing."""
    eng = _engine(params)
    d_args = decode_example_args(eng)
    undonated = jax.jit(partial(eng._decode_fn, do_sample=False))
    with eng._scope():
        low = undonated.lower(eng.params, eng.cache, *d_args)
    r = audit_donation("inject/undonated", low)
    assert not r.ok
    assert "not marked for donation" in _messages(r)


def test_dtype_injection_caught_at_expert_matmul(monkeypatch):
    """Disabling quantization inside the MoE expert matmul (the exact
    precision leak PR 8 fixed) must fail dtype-purity with provenance
    pointing at _expert_matmul — while the router's f32 gate in
    moe_apply stays allowlisted."""
    from repro.models import moe
    params = init_params(jax.random.PRNGKey(0), JAMBA)
    eng = _engine(params, cfg=JAMBA, datapath="sc_int")
    orig = moe._expert_matmul
    monkeypatch.setattr(
        moe, "_expert_matmul",
        lambda p, x, quant, spec: orig(p, x, quant.with_mode("none"),
                                       spec))
    d_args = decode_example_args(eng)
    with eng._scope():
        jx = jax.make_jaxpr(partial(eng._decode_fn, do_sample=False))(
            eng.params, eng.cache, *d_args)
    r = audit_dtype_purity("inject/float-expert", jx, datapath="sc_int")
    assert not r.ok
    assert "models/moe.py:_expert_matmul" in _messages(r)
    assert "sc_int BSN region" in _messages(r)


def test_dtype_engagement_check(params):
    """A 'quantized' datapath whose jaxpr contains zero integer dots is
    flagged — the audit must not pass vacuously when quantization
    silently turns itself off."""
    eng = _engine(params)                       # qat: float projections
    d_args = decode_example_args(eng)
    with eng._scope():
        jx = jax.make_jaxpr(partial(eng._decode_fn, do_sample=False))(
            eng.params, eng.cache, *d_args)
    r = audit_dtype_purity("inject/not-engaged", jx, datapath="sc_int")
    assert not r.ok
    assert "not" in _messages(r) and "engaged" in _messages(r)


def test_host_boundary_injection_caught(params):
    """A pure_callback smuggled into a traced step is flagged."""
    eng = _engine(params)
    d_args = decode_example_args(eng)

    def leaky(p, cache, *args):
        out, cache, _ = eng._decode_fn(p, cache, *args, do_sample=False)
        lead = jax.tree.leaves(out)[0]
        peek = jax.pure_callback(
            lambda x: x, jax.ShapeDtypeStruct(lead.shape, lead.dtype),
            lead)
        return peek, cache

    with eng._scope():
        jx = jax.make_jaxpr(leaky)(eng.params, eng.cache, *d_args)
    r = audit_host_boundary("inject/callback", jx)
    assert not r.ok
    assert "pure_callback" in _messages(r)


# ---------------------------------------------------------------------------
# sharding (needs >= 4 devices; tier-1 enters via the subprocess wrapper)
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices — set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@needs_mesh
def test_sharding_audit_clean_on_mesh(params):
    rules = serving_rules(make_serving_mesh(model_parallel=2,
                                            data_parallel=2))
    eng = _engine(params, datapath="sc_int", kv_format="sc",
                  mesh_rules=rules)
    r = audit_sharding(eng, "mesh/clean")
    assert r.ok, _messages(r)
    assert any("sharded" in n for n in r.notes)


@needs_mesh
def test_sharding_injection_caught(params):
    """One pool leaf replaced with a replicated copy must be flagged
    with the leaf path and the expected spec."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rules = serving_rules(make_serving_mesh(model_parallel=2,
                                            data_parallel=2))
    eng = _engine(params, datapath="sc_int", kv_format="sc",
                  mesh_rules=rules)
    cache = jax.tree.map(lambda a: a, eng.cache)
    leaf = cache["periods"]["p0"]["k_pages"]
    cache["periods"]["p0"]["k_pages"] = jax.device_put(
        leaf, NamedSharding(rules.mesh, P()))
    r = audit_sharding(eng, "inject/replicated", cache=cache,
                       check_collectives=False)
    assert not r.ok
    assert "k_pages" in _messages(r) and "model" in _messages(r)


def test_sharding_subprocess():
    """Tier-1 entry to the mesh audit tests: forced host-device count
    must be set before jax initializes, so fresh interpreter."""
    if jax.device_count() >= 4:
        pytest.skip("mesh audit tests run natively in this process")
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.path.join(os.path.dirname(here), "src")
        + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join(here, "test_contracts.py"), "-k", "sharding"],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]


# ---------------------------------------------------------------------------
# the per-prompt recompile regression (satellite fix, engine.py oracle)
# ---------------------------------------------------------------------------

def test_paged_oracle_does_not_retrace_per_prompt(params):
    """The paged sequential oracle's jits are module-level and keyed on
    statics: a second identical sequential_generate call must add ZERO
    lowerings (the per-prompt ``jax.jit(lambda ...)`` wrapper it
    replaces re-traced every prompt of every call)."""
    fns = (engine_mod._oracle_paged_prefill,
           engine_mod._oracle_paged_decode)
    if not all(hasattr(f, "_cache_size") for f in fns):
        pytest.skip("jit cache introspection unavailable on this jax")
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]

    def run():
        return sequential_generate(params, CFG, prompts,
                                   max_new_tokens=4, max_len=32,
                                   kv_format="sc", datapath="sc_int")

    first = run()
    sizes = [f._cache_size() for f in fns]
    second = run()
    assert second == first
    assert [f._cache_size() for f in fns] == sizes, \
        "paged oracle re-traced on an identical repeated workload"

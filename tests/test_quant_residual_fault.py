"""QAT quantizers (§III-B), residual re-scaling (§III-C), fault injection (Fig 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import coding, fault, quant, residual


# ---------------------------------------------------------------------------
# LSQ fake quant
# ---------------------------------------------------------------------------

def test_fake_quant_forward_values():
    x = jnp.asarray([-3.0, -0.6, -0.2, 0.0, 0.3, 0.6, 3.0])
    out = quant.lsq_fake_quant(x, jnp.asarray(0.5), -1, 1)
    np.testing.assert_allclose(np.asarray(out),
                               [-0.5, -0.5, 0.0, 0.0, 0.5, 0.5, 0.5])


def test_ste_gradient_masks_clip():
    x = jnp.asarray([-3.0, 0.2, 3.0])
    g = jax.grad(lambda x: quant.lsq_fake_quant(x, jnp.asarray(0.5), -1, 1).sum())(x)
    np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 0.0])


def test_alpha_gradient_lsq_formula():
    x = jnp.asarray([0.3])                     # x/a = 0.6 -> q=1
    a = jnp.asarray(0.5)
    g = jax.grad(lambda a: quant.lsq_fake_quant(x, a, -1, 1).sum())(a)
    # d/da = q - x/a = 1 - 0.6 = 0.4, times grad scale 1/sqrt(1*1)
    np.testing.assert_allclose(float(g), 0.4, rtol=1e-6)
    # saturated sample contributes the rail value
    g2 = jax.grad(lambda a: quant.lsq_fake_quant(
        jnp.asarray([3.0]), a, -1, 1).sum())(a)
    np.testing.assert_allclose(float(g2), 1.0, rtol=1e-6)


def test_per_channel_alpha_broadcast_and_grad_shape():
    x = jax.random.normal(jax.random.key(0), (5, 3))
    a = jnp.asarray([0.3, 0.5, 1.0])
    out = quant.lsq_fake_quant(x, a, -4, 4)
    assert out.shape == x.shape
    ga = jax.grad(lambda a: quant.lsq_fake_quant(x, a, -4, 4).sum())(a)
    assert ga.shape == a.shape


@given(st.integers(0, 10), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=20, deadline=None)
def test_act_quant_matches_inference_quantizer(seed, bsl):
    """QAT rounding == coding.quantize_levels (training/inference parity)."""
    x = jax.random.normal(jax.random.key(seed), (32,))
    alpha = 0.3
    fq = quant.thermometer_act_quant(x, jnp.asarray(alpha), bsl)
    q = coding.quantize_levels(x, alpha, bsl)
    np.testing.assert_allclose(np.asarray(fq),
                               np.asarray(q, np.float32) * alpha, rtol=1e-6)


# ---------------------------------------------------------------------------
# residual re-scaling block
# ---------------------------------------------------------------------------

def test_rescale_multiply_exact():
    v = jnp.arange(-8, 9)
    np.testing.assert_array_equal(np.asarray(residual.rescale_q(v, 3)),
                                  np.asarray(v) * 8)


@given(st.integers(-8, 8), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_rescale_divide_matches_bit_level(v, n):
    """q-domain divide == the paper's bit-level 1-of-2 subsample + pad."""
    bits = coding.encode_thermometer(jnp.asarray(v), 16)
    for _ in range(n):
        bits = residual.rescale_bits_div2(bits)
        assert bits.shape[-1] == 16                       # constant BSL
        # output is a concatenation of thermometer codes (BSN-input valid);
        # its VALUE is still popcount - L/2:
    got = int(coding.decode_thermometer(bits))
    expect = int(residual.rescale_q(jnp.asarray(v), -n))
    assert got == expect
    # error vs exact division bounded by 1 level per cycle
    assert abs(got - v / 2 ** n) <= 1.0


def test_pow2_exponent():
    assert residual.pow2_exponent(0.25, 1.0) == 2
    assert residual.pow2_exponent(1.0, 0.25) == -2
    assert residual.pow2_exponent(0.3, 1.0) == 2          # nearest pow2


def test_residual_add():
    conv = jnp.asarray([10, -5])
    resid = jnp.asarray([3, 3])
    np.testing.assert_array_equal(
        np.asarray(residual.residual_add_q(conv, resid, 2)), [22, 7])


# ---------------------------------------------------------------------------
# fault injection: thermometer degrades gracefully, binary doesn't
# ---------------------------------------------------------------------------

def test_zero_ber_identity():
    xq = jnp.arange(-8, 9)
    out = fault.thermometer_under_ber(xq, 16, 0.0, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(xq))
    outb = fault.binary_under_ber(xq, 5, 0.0, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(outb), np.asarray(xq))


def test_thermometer_vs_binary_mse_at_equal_ber():
    """Fig 5 mechanism: at the same BER, thermometer MSE << binary MSE
    (binary flips hit exponentially-weighted positions)."""
    key = jax.random.key(42)
    xq = jax.random.randint(key, (20000,), -8, 9)
    ber = 0.05
    th = fault.thermometer_under_ber(xq, 16, ber, jax.random.key(1))
    bi = fault.binary_under_ber(xq, 16, ber, jax.random.key(2))
    mse_th = float(jnp.mean((th - xq) ** 2))
    mse_bi = float(jnp.mean((bi - xq) ** 2))
    assert mse_th < mse_bi / 10, (mse_th, mse_bi)


def test_binary_roundtrip_no_noise_negative():
    xq = jnp.asarray([-8, -1, 0, 7])
    out = fault.binary_under_ber(xq, 4, 0.0, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(xq))

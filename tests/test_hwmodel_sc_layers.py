"""Hardware cost model calibration (Table V) + SC layer path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsn, hwmodel, sc_layers, si
from repro.core.sc_layers import SCQuantConfig


# ---------------------------------------------------------------------------
# hwmodel: calibration + ratio predictions
# ---------------------------------------------------------------------------

def test_baseline_bsn_matches_table_v():
    """3x3x512 conv: 9216 bits. Calibrated to area 2.95e5, delay 4.33."""
    cost = hwmodel.bsn_cost(9216)
    np.testing.assert_allclose(cost.area_um2, 2.95e5, rtol=1e-6)
    np.testing.assert_allclose(cost.delay_ns, 4.33, rtol=1e-6)
    np.testing.assert_allclose(cost.adp, 1.26e6, rtol=0.02)   # paper: 1.26e6


def test_superlinear_growth_fig9a():
    """Fig 9a: BSN cost grows superlinearly with accumulation width."""
    a1 = hwmodel.bsn_cost(256).area_um2
    a2 = hwmodel.bsn_cost(512).area_um2
    assert a2 > 2.0 * a1


def test_small_width_overhead_fig9b():
    """Fig 9b: using the 9216-bit BSN for a 256-bit accumulation wastes
    >10x ADP vs a right-sized BSN."""
    big = hwmodel.bsn_cost(9216).adp
    small = hwmodel.bsn_cost(256).adp
    assert big / small > 10


def test_spatial_approx_reduces_adp():
    """§IV-C: a progressive-sorting spec for the 4608-product conv cuts ADP
    by >= 2x vs the baseline BSN (paper: 2.8x)."""
    base = hwmodel.bsn_cost(9216)
    spec = bsn.ApproxBSNSpec(
        width=4608, in_bsl=2,
        stages=(bsn.StageSpec(64, bsn.SubSampleSpec(clip=48, stride=1)),
                bsn.StageSpec(72, bsn.SubSampleSpec(clip=1136, stride=8)),))
    appr = hwmodel.approx_bsn_cost(spec)
    assert appr.adp < base.adp / 2, (appr.adp, base.adp)


def test_temporal_fold_reduces_area():
    spec = bsn.ApproxBSNSpec(
        width=512, in_bsl=2,
        stages=(bsn.StageSpec(512, bsn.SubSampleSpec(clip=448, stride=2)),))
    st_cost = hwmodel.spatial_temporal_cost(spec, cycles=9)
    base = hwmodel.bsn_cost(9216)
    assert st_cost.area_um2 < base.area_um2 / 10


def test_tops_per_watt_calibration():
    np.testing.assert_allclose(hwmodel.tops_per_watt(2, 0.65), 198.9,
                               rtol=1e-6)
    # Fig 2/Table IV direction: higher BSL -> lower efficiency
    assert hwmodel.tops_per_watt(8) < hwmodel.tops_per_watt(2) / 2
    # voltage scaling direction (Fig 4)
    assert hwmodel.tops_per_watt(2, 0.9) < hwmodel.tops_per_watt(2, 0.65)


# ---------------------------------------------------------------------------
# sc_layers: QAT == integer == bit-exact equivalence
# ---------------------------------------------------------------------------

CFG = SCQuantConfig(mode="sc_qat", act_bsl=8, per_channel=False)


def _params(key, din=32, dout=16):
    return sc_layers.init_sc_linear(key, din, dout, CFG)


def test_qat_equals_int_path():
    """fake-quant matmul == alpha_a*alpha_w * integer matmul."""
    key = jax.random.key(0)
    p = _params(key)
    x = jax.random.normal(jax.random.key(1), (4, 32))
    y_qat = sc_layers.sc_linear_qat(p, x, CFG)
    exported = sc_layers.export_sc_linear(p, CFG)
    from repro.core.coding import quantize_levels
    x_q = quantize_levels(x, float(p["alpha_a"]), CFG.act_bsl)
    y_int = sc_layers.sc_linear_int(exported, x_q)
    scale = float(p["alpha_a"]) * float(p["alpha_w"])
    np.testing.assert_allclose(np.asarray(y_qat),
                               np.asarray(y_int) * scale, rtol=1e-5, atol=1e-5)


def test_int_path_equals_bitstream_path():
    """int matmul accumulate == multiplier + BSN popcount, bit-for-bit."""
    from repro.core import coding, multiplier
    rng = np.random.default_rng(0)
    din = 8
    x_q = jnp.asarray(rng.integers(-4, 5, (din,)))
    w_int = jnp.asarray(rng.integers(-1, 2, (din, 3)), jnp.int8)
    # integer path
    y_int = np.asarray(x_q @ w_int.astype(jnp.int32))
    # bit path, per output neuron
    bits = coding.encode_thermometer(x_q, 8)
    for j in range(3):
        prods = multiplier.ternary_scale_bits(w_int[:, j], bits)
        sorted_bits = bsn.exact_bsn_bits(prods)
        val = int(coding.counts_from_bits(sorted_bits)) - din * 8 // 2
        assert val == y_int[j]


def test_int_path_with_si_epilogue():
    key = jax.random.key(2)
    p = _params(key, din=16, dout=4)
    x = jax.random.normal(jax.random.key(3), (5, 16)) * 0.5
    exported = sc_layers.export_sc_linear(
        p, CFG, act_fn=si.relu_fn, out_bsl=16,
        alpha_out=float(p["alpha_a"]))
    from repro.core.coding import quantize_levels
    x_q = quantize_levels(x, float(p["alpha_a"]), CFG.act_bsl)
    y = sc_layers.sc_linear_int(exported, x_q)
    # reference: relu of the dequantized sum, requantized at alpha_out
    sum_q = np.asarray(x_q @ jnp.asarray(exported["w_int"], jnp.int32))
    scale = float(p["alpha_a"]) * float(np.atleast_1d(exported["alpha_w"])[0])
    ref = np.maximum(sum_q * scale, 0.0)
    ref_q = np.clip(np.round(ref / float(p["alpha_a"])), -8, 8)
    np.testing.assert_array_equal(np.asarray(y), ref_q)


def test_per_channel_export():
    cfg = SCQuantConfig(mode="sc_qat", act_bsl=8, per_channel=True)
    p = sc_layers.init_sc_linear(jax.random.key(0), 16, 4, cfg)
    exported = sc_layers.export_sc_linear(
        p, cfg, act_fn=si.relu_fn, out_bsl=16, alpha_out=0.25)
    assert exported["thresholds"].shape == (4, 16)
    x_q = jnp.asarray(np.random.default_rng(0).integers(-4, 5, (2, 16)))
    y = sc_layers.sc_linear_int(exported, x_q)
    assert y.shape == (2, 4)
    assert np.all(np.asarray(y) >= -8) and np.all(np.asarray(y) <= 8)


def test_mode_none_passthrough():
    p = sc_layers.init_sc_linear(jax.random.key(0), 8, 8, sc_layers.SC_OFF)
    assert "alpha_w" not in p
    x = jax.random.normal(jax.random.key(1), (2, 8))
    y = sc_layers.sc_linear_qat(p, x, sc_layers.SC_OFF)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ p["w"]))

"""Bitonic sorting network adder (paper §II-B, §IV-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bsn, coding


# ---------------------------------------------------------------------------
# the sorter itself
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 16, 64, 33, 100])
@pytest.mark.parametrize("descending", [True, False])
def test_bitonic_matches_jnp_sort(n, descending):
    x = jax.random.randint(jax.random.key(n), (5, n), -100, 100, jnp.int32)
    got = bsn.bitonic_sort(x, descending=descending)
    ref = jnp.sort(x, axis=-1)
    if descending:
        ref = ref[..., ::-1]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_bitonic_float():
    x = jax.random.normal(jax.random.key(0), (3, 17))
    got = bsn.bitonic_sort(x, descending=True)
    ref = jnp.sort(x, axis=-1)[..., ::-1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# exact BSN accumulation: sorted popcount == sum (paper's central identity)
# ---------------------------------------------------------------------------

@given(st.integers(0, 6), st.sampled_from([2, 4, 8]), st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_exact_bsn_bits_equals_counts(seed, bsl, width):
    key = jax.random.key(seed)
    half = bsl // 2
    levels = jax.random.randint(key, (width,), -half, half + 1)
    bits = coding.encode_thermometer(levels, bsl)
    sorted_bits = bsn.exact_bsn_bits(bits)
    # output is a valid thermometer code of the concatenated length
    assert coding.is_thermometer(np.asarray(sorted_bits)[None])[0]
    # popcount - N*L/2 == sum of levels
    total = int(coding.counts_from_bits(sorted_bits)) - width * bsl // 2
    assert total == int(jnp.sum(levels))
    # functional path agrees
    counts = coding.counts_from_bits(bits)
    assert int(bsn.exact_bsn_counts(counts)) == int(jnp.sum(counts))


def test_exact_bsn_batched():
    key = jax.random.key(1)
    levels = jax.random.randint(key, (4, 8), -2, 3)
    bits = coding.encode_thermometer(levels, 4)
    sorted_bits = bsn.exact_bsn_bits(bits)
    got = coding.counts_from_bits(sorted_bits) - 8 * 4 // 2
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.sum(levels, -1)))


# ---------------------------------------------------------------------------
# approximate spatial BSN: bit path == count path, error bounds
# ---------------------------------------------------------------------------

def _spec(width=8, in_bsl=4, clip=2, stride=2):
    sorted_len = width * in_bsl
    return bsn.ApproxBSNSpec(
        width=width, in_bsl=in_bsl,
        stages=(bsn.StageSpec(group=width,
                              sub=bsn.SubSampleSpec(clip=clip, stride=stride)),))


@given(st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_approx_bits_equals_counts_single_stage(seed):
    spec = _spec()
    key = jax.random.key(seed)
    levels = jax.random.randint(key, (3, spec.width), -2, 3)
    bits = coding.encode_thermometer(levels, spec.in_bsl)
    got_bits = bsn.approx_bsn_bits(bits, spec)
    assert np.all(coding.is_thermometer(np.asarray(got_bits)))
    from_bits = coding.counts_from_bits(got_bits)
    from_counts = bsn.approx_bsn_counts(coding.counts_from_bits(bits), spec)
    np.testing.assert_array_equal(np.asarray(from_bits),
                                  np.asarray(from_counts))


@given(st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_approx_bits_equals_counts_two_stage(seed):
    spec = bsn.ApproxBSNSpec(
        width=16, in_bsl=4,
        stages=(bsn.StageSpec(4, bsn.SubSampleSpec(clip=4, stride=1)),
                bsn.StageSpec(4, bsn.SubSampleSpec(clip=8, stride=2))))
    key = jax.random.key(seed)
    levels = jax.random.randint(key, (spec.width,), -2, 3)
    bits = coding.encode_thermometer(levels, spec.in_bsl)
    got_bits = bsn.approx_bsn_bits(bits, spec)
    from_bits = int(coding.counts_from_bits(got_bits))
    from_counts = int(bsn.approx_bsn_counts(coding.counts_from_bits(bits),
                                            spec))
    assert from_bits == from_counts


def test_no_clip_no_stride_is_exact():
    spec = bsn.ApproxBSNSpec(
        width=8, in_bsl=4,
        stages=(bsn.StageSpec(8, bsn.SubSampleSpec(0, 1)),))
    levels = jnp.asarray([2, -2, 1, 0, -1, 2, 2, -2])
    counts = coding.encode_thermometer(levels, 4).sum(-1)
    out = int(bsn.approx_bsn_counts(counts, spec))
    # exact: out count == total count, value == sum
    assert out - 8 * 4 // 2 == int(levels.sum())


def test_clipping_saturates_extremes():
    spec = _spec(width=4, in_bsl=4, clip=6, stride=1)
    # all +2 -> sum 8, count 16; clipped to 16-12=4 wide window
    counts = jnp.full((4,), 4)
    out = int(bsn.approx_bsn_counts(counts, spec))
    assert out == 4            # saturated at the top of the window


@given(st.integers(0, 30))
@settings(max_examples=30, deadline=None)
def test_stride_error_bound(seed):
    """Sub-sampling by s quantizes: |value_error| <= s/2 when not clipped."""
    spec = _spec(width=8, in_bsl=8, clip=0, stride=4)
    key = jax.random.key(seed)
    levels = jax.random.randint(key, (8,), -4, 5)
    counts = levels + 4
    out = int(bsn.approx_bsn_counts(counts, spec))
    value = spec.scale * (out - spec.out_bsl // 2)
    assert abs(value - int(levels.sum())) <= spec.scale // 2


# ---------------------------------------------------------------------------
# spatial-temporal folding (Fig 12)
# ---------------------------------------------------------------------------

def test_spatial_temporal_matches_per_chunk():
    spec = _spec(width=8, in_bsl=4, clip=0, stride=2)
    key = jax.random.key(3)
    levels = jax.random.randint(key, (5, 72), -2, 3)   # 9 cycles of 8
    counts = levels + 2
    got = bsn.spatial_temporal_counts(counts, spec, cycles=9)
    chunks = counts.reshape(5, 9, 8)
    expect = bsn.approx_bsn_counts(chunks, spec).sum(-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    # value semantics: scale*(out - cycles*out_bsl/2) approximates the sum
    value = spec.scale * (np.asarray(got) - 9 * spec.out_bsl // 2)
    exact = np.asarray(levels.sum(-1))
    assert np.max(np.abs(value - exact)) <= 9 * spec.scale  # rounding per cycle


# ---------------------------------------------------------------------------
# property tests: BSN invariants on near-Gaussian inputs
# ---------------------------------------------------------------------------

@given(st.integers(0, 10 ** 6), st.sampled_from([2, 4, 8]),
       st.integers(4, 64))
@settings(max_examples=25, deadline=None)
def test_exact_sort_preserves_popcount_gaussian(seed, bsl, width):
    """Invariant: popcount(exact_bsn_bits(x)) == sum of input popcounts.

    The sort only permutes wires, so total switched charge is conserved —
    the paper's central identity.  Inputs are near-Gaussian (binomial
    counts), the regime the approximate design assumes.
    """
    rng = np.random.default_rng(seed)
    counts = rng.binomial(bsl, 0.5, size=(3, width))
    levels = jnp.asarray(counts - bsl // 2)
    bits = coding.encode_thermometer(levels, bsl)
    sorted_bits = bsn.exact_bsn_bits(bits)
    np.testing.assert_array_equal(
        np.asarray(coding.counts_from_bits(sorted_bits)),
        np.asarray(coding.counts_from_bits(bits)).sum(-1))


def _clip_mass_bound(spec: bsn.ApproxBSNSpec) -> float:
    """Worst-case |value error| of the pipeline.

    Stage i runs ``n_i = width / prod(g_1..g_i)`` parallel sub-BSNs; each
    can saturate away at most its clipped tail mass (clip_i) and rounds by
    at most stride_i/2, in stage-i count units = prod of earlier strides
    in input units.  Parallel sub-BSN errors add downstream, hence n_i."""
    bound, prefix, n = 0.0, 1.0, spec.width
    for s in spec.stages:
        n //= s.group
        bound += prefix * n * (s.sub.clip + s.sub.stride / 2)
        prefix *= s.sub.stride
    return bound


@given(st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_approx_error_bounded_by_clip_mass(seed):
    """Invariant: |approx value - exact sum| <= sum_i prefix_i * (clip_i +
    stride_i/2) for ANY input; near-Gaussian draws keep it far below."""
    rng = np.random.default_rng(seed)
    in_bsl = int(rng.choice([2, 4, 8]))
    g1, g2 = int(rng.choice([2, 4])), int(rng.choice([2, 4, 8]))
    s1_len = in_bsl * g1
    spec = bsn.ApproxBSNSpec(
        width=g1 * g2, in_bsl=in_bsl,
        stages=(bsn.StageSpec(g1, bsn.SubSampleSpec(
            clip=int(rng.integers(0, s1_len // 4 + 1)), stride=1)),
                bsn.StageSpec(g2, bsn.SubSampleSpec(clip=0, stride=2))))
    counts = jnp.asarray(rng.binomial(in_bsl, 0.5, size=(8, spec.width)))
    out = bsn.approx_bsn_counts(counts, spec)
    value = spec.scale * (np.asarray(out) - spec.out_bsl / 2)
    exact = np.asarray(counts.sum(-1)) - spec.width * in_bsl / 2
    assert np.max(np.abs(value - exact)) <= _clip_mass_bound(spec) + 1e-9


def test_spec_validation():
    with pytest.raises(ValueError):
        bsn.ApproxBSNSpec(width=8, in_bsl=4,
                          stages=(bsn.StageSpec(4, bsn.SubSampleSpec(0, 1)),))
    with pytest.raises(ValueError):                     # stride doesn't divide
        bsn.ApproxBSNSpec(width=4, in_bsl=4,
                          stages=(bsn.StageSpec(4, bsn.SubSampleSpec(1, 4)),))

"""Shared test bootstrap.

1. Make ``src/`` importable even when PYTHONPATH isn't set (the tier-1
   command sets it; IDE runs often don't).
2. If the real ``hypothesis`` package is missing, install the
   deterministic fallback from ``repro.testing.property_fallback`` so the
   suite degrades to fixed example sweeps instead of failing collection.
   Declare/install the real dependency via ``requirements-test.txt``.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    from repro.testing.property_fallback import install_as_hypothesis
    install_as_hypothesis()

"""AST lint rules: repo-clean assertion + one synthetic injection per
rule.  Each injection is a minimal source tree containing exactly one
hazard; the rule must flag it with the right name and line."""

from repro.analysis.lint import lint_repo, lint_sources


def _rules(vios):
    return {v.rule for v in vios}


def test_repo_is_lint_clean():
    """The package's own tree carries zero lint violations — the gate
    starts from a clean baseline."""
    vios = lint_repo()
    assert vios == [], [v.to_dict() for v in vios]


def test_host_op_item_reachable_from_root():
    files = {"repro/serving/hot.py": (
        "import jax\n"
        "def helper(x):\n"
        "    return x.sum().item()\n"
        "def decode(x):\n"
        "    return helper(x)\n")}
    vios = lint_sources(files, roots=(("serving/hot.py", "decode"),))
    assert _rules(vios) == {"host-op"}
    assert ".item()" in vios[0].message and vios[0].line == 3


def test_host_op_numpy_alias_and_suppression():
    files = {"repro/serving/hot.py": (
        "import numpy as np\n"
        "def decode(shape):\n"
        "    a = np.prod(shape)\n"
        "    b = np.prod(shape)  # lint: host-ok\n"
        "    return a + b\n")}
    vios = lint_sources(files, roots=(("serving/hot.py", "decode"),))
    # the marked line is suppressed; the unmarked one is flagged
    assert [v.line for v in vios if v.rule == "host-op"] == [3]


def test_host_op_unreachable_is_ignored():
    """Host ops in functions NOT reachable from a traced root are fine —
    the rule guards the hot path, not the whole package."""
    files = {"repro/serving/hot.py": (
        "def decode(x):\n"
        "    return x\n"
        "def offline_report(x):\n"
        "    return x.item()\n")}
    vios = lint_sources(files, roots=(("serving/hot.py", "decode"),))
    assert vios == []


def test_blockspec_arity_mismatch():
    files = {"repro/kernels/k.py": (
        "import jax\n"
        "from jax.experimental import pallas as pl\n"
        "def launch(x):\n"
        "    return pl.pallas_call(\n"
        "        lambda ref, o: None,\n"
        "        grid=(4, 4),\n"
        "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
        "        out_shape=None)(x)\n")}
    vios = lint_sources(files)
    assert _rules(vios) == {"blockspec-arity"}
    assert len(vios) == 1 and vios[0].line == 7    # the 1-arg index map


def test_static_argnames_missing_bool():
    files = {"repro/models/m.py": (
        "import jax\n"
        "from functools import partial\n"
        "@partial(jax.jit, static_argnames=('mode',))\n"
        "def step(x, *, mode: str = 'fast', causal: bool = True):\n"
        "    return x\n")}
    vios = lint_sources(files)
    assert _rules(vios) == {"static-argnames"}
    assert "causal" in vios[0].message


def test_static_argnames_array_kwarg_ok():
    """Array-typed keyword args stay traced — the rule only demands
    statics for bool/str params (the paged-attention kernels' k_scale /
    v_resid pools are the motivating case)."""
    files = {"repro/models/m.py": (
        "import jax\n"
        "from functools import partial\n"
        "@partial(jax.jit, static_argnames=('causal',))\n"
        "def step(x, *, causal: bool = True,\n"
        "         k_scale: jax.Array | None = None):\n"
        "    return x\n")}
    assert lint_sources(files) == []


def test_jit_in_loop():
    files = {"repro/serving/o.py": (
        "import jax\n"
        "def oracle(prompts, f):\n"
        "    outs = []\n"
        "    for p in prompts:\n"
        "        outs.append(jax.jit(lambda t: f(t))(p))\n"
        "    return outs\n")}
    vios = lint_sources(files)
    assert _rules(vios) == {"jit-in-loop"}
    assert vios[0].line == 5 and "re-traces" in vios[0].message


def test_stale_root_is_reported():
    """A traced root that no longer exists must fail loudly, not let the
    host-op walk silently cover nothing."""
    files = {"repro/serving/hot.py": "def decode(x):\n    return x\n"}
    vios = lint_sources(files, roots=(("serving/hot.py", "gone_fn"),))
    assert vios and all("gone_fn" in v.message for v in vios)

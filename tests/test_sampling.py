"""Seeded stochastic sampling: filter laws + the engine differentials.

Two layers:

1. Unit laws of the pure sampler (serving/sampling.py): temperature=0 is
   exact argmax, top-k=1 is greedy at any temperature, top-p mass
   boundary ties are all kept (the kept set is a pure function of the
   logit row, never of sort tie order), min-p thresholds against the
   row's best token, and the (seed, position) stream draws the same
   token no matter which lane / batch width carries it.
2. The seeded differential family the greedy-only engine could never
   express: batched continuous-batching decode == per-request sequential
   decode under nontrivial temperature / top-p, per datapath, invariant
   across retrace buckets and across preemption (the mesh third of the
   family lives in test_sharded_serving.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import (SamplingParams, ServeEngine,
                           sequential_generate)
from repro.serving.sampling import (filter_logits, pack_sampling,
                                    sample_tokens)

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)
CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [10, 11, 12, 13, 14]]
SAMPLED = [SamplingParams(temperature=0.9, top_p=0.8, top_k=16,
                          seed=100 + i) for i in range(len(PROMPTS))]


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.key(0), CFG)


def _run_engine(params, prompts, sps, max_new=5, eos_id=None, **kw):
    eng = ServeEngine(params, CFG, **kw)
    for p, sp in zip(prompts, sps):
        eng.submit(p, max_new_tokens=max_new, eos_id=eos_id, sampling=sp)
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    return [r.generated for r in sorted(done, key=lambda r: r.rid)]


def _kept(masked_row):
    """Indices surviving the filters (finite entries)."""
    return set(np.flatnonzero(np.isfinite(np.asarray(masked_row))))


def _filter_one(logits_row, sp: SamplingParams):
    samp = pack_sampling([sp])
    return filter_logits(jnp.asarray(logits_row, jnp.float32)[None],
                         samp["temperature"], samp["top_k"],
                         samp["top_p"], samp["min_p"])[0]


# ---------------------------------------------------------------------------
# 1. unit laws
# ---------------------------------------------------------------------------

def test_params_validation():
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(min_p=-0.2), dict(min_p=1.1)):
        with pytest.raises(ValueError):
            SamplingParams(**bad)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_temperature_zero_is_exact_argmax():
    """Greedy is the temperature=0 special case: other controls are
    ignored and the draw is the bit-exact argmax of the cropped row —
    the old greedy-only engine's behavior."""
    logits = jax.random.normal(jax.random.key(0), (5, 48))
    samp = pack_sampling([SamplingParams(top_k=3, top_p=0.5, min_p=0.3,
                                         seed=s) for s in range(5)])
    pos = jnp.arange(5, dtype=jnp.int32)
    got = sample_tokens(logits, pos, samp, vocab_size=48)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_top_k1_equals_greedy_at_any_temperature():
    logits = jax.random.normal(jax.random.key(1), (6, 40))
    for temp in (0.3, 1.0, 7.5):
        samp = pack_sampling([SamplingParams(temperature=temp, top_k=1,
                                             seed=s) for s in range(6)])
        got = sample_tokens(logits, jnp.arange(6, dtype=jnp.int32),
                            samp, vocab_size=40)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.argmax(logits, -1)))


def test_top_p_mass_boundary_ties_all_kept():
    """Four tokens tied at p~=0.25 with top_p=0.5: the strict prefix
    holds 2 (or 3) of them depending on float rounding and sort order —
    the tie rule must widen to ALL FOUR, so the kept set is a pure
    function of the row and boundary ties can never break slot/bucket
    invariance.  The tiny-tail tokens stay excluded."""
    probs = np.full(8, 1e-9)
    probs[[1, 3, 4, 6]] = 0.25
    row = np.log(probs)
    kept = _kept(_filter_one(row, SamplingParams(temperature=1.0,
                                                 top_p=0.5)))
    assert kept == {1, 3, 4, 6}


def test_top_p_prefix_rule():
    """No ties: probs (.5, .3, .2) with top_p=0.6 keeps exactly the
    shortest prefix whose preceding mass is < 0.6 — tokens {0, 1}."""
    row = np.log(np.array([0.5, 0.3, 0.2]))
    kept = _kept(_filter_one(row, SamplingParams(temperature=1.0,
                                                 top_p=0.6)))
    assert kept == {0, 1}


def test_min_p_thresholds_against_best():
    """min_p=0.1 with best prob .5: threshold .05 cuts the .04 token."""
    row = np.log(np.array([0.5, 0.3, 0.12, 0.04, 0.04]))
    kept = _kept(_filter_one(row, SamplingParams(temperature=1.0,
                                                 min_p=0.1)))
    assert kept == {0, 1, 2}


def test_temperature_extremes():
    """t -> 0+ concentrates on the argmax; t -> inf flattens but must
    stay inside the top-k set (the filter, not the temperature, bounds
    the support)."""
    logits = jnp.asarray(np.linspace(0.0, 8.0, 32), jnp.float32)[None]
    top4 = set(range(28, 32))
    cold = hot = set()
    for pos in range(40):
        p = jnp.asarray([pos], jnp.int32)
        tc = sample_tokens(logits, p, pack_sampling(
            [SamplingParams(temperature=1e-4, seed=3)]), 32)
        cold = cold | {int(tc[0])}
        th = sample_tokens(logits, p, pack_sampling(
            [SamplingParams(temperature=1e4, top_k=4, seed=3)]), 32)
        hot = hot | {int(th[0])}
    assert cold == {31}                     # effectively greedy
    assert hot <= top4 and len(hot) > 1     # spread, but filtered


def test_same_seed_position_same_draw_any_lane_any_width():
    """The stream is (seed, position) ONLY: identical rows with the same
    seed/position draw the same token in every lane of a wide batch, and
    that token equals the batch-1 draw (the oracle's shape)."""
    row = jax.random.normal(jax.random.key(2), (24,))
    sp = SamplingParams(temperature=1.2, top_p=0.95, seed=42)
    pos = jnp.full((4,), 9, jnp.int32)
    wide = sample_tokens(jnp.tile(row[None], (4, 1)), pos,
                         pack_sampling([sp] * 4), 24)
    assert len(set(np.asarray(wide).tolist())) == 1
    one = sample_tokens(row[None], pos[:1], pack_sampling([sp]), 24)
    assert int(one[0]) == int(wide[0])


def test_positions_advance_the_stream():
    """Successive positions under one seed must not replay the draw."""
    row = jnp.zeros((1, 16), jnp.float32)       # uniform: pure RNG
    sp = pack_sampling([SamplingParams(temperature=1.0, seed=0)])
    toks = {int(sample_tokens(row, jnp.asarray([t], jnp.int32),
                              sp, 16)[0]) for t in range(32)}
    assert len(toks) > 4


# ---------------------------------------------------------------------------
# 2. engine differentials (batched == sequential, seeded)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
def test_sampled_batched_equals_sequential_per_datapath(params, datapath):
    """The acceptance differential's local two-thirds: seeded sampled
    decode (temperature>0, top-p<1) is token-identical between the
    batched paged engine and the sequential oracle on every datapath."""
    got = _run_engine(params, PROMPTS, SAMPLED, max_slots=3, max_len=32,
                      page_size=8, datapath=datapath)
    ref = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                              max_len=32, datapath=datapath,
                              sampling=SAMPLED)
    assert got == ref, datapath
    greedy = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                                 max_len=32, datapath=datapath)
    assert got != greedy, "sampling degenerated to greedy"


def test_mixed_greedy_and_sampled_batch(params):
    """Greedy (default / None) and sampled requests share one decode
    step; each lane follows its own rule.  This also pins the
    bit-identity of the two compiled paths: the engine's mixed batch
    runs greedy lanes through the sampled step's in-trace argmax
    branch, while the oracle's greedy requests take the dedicated
    argmax-only step — the tokens must agree."""
    sps = [None, SAMPLED[1], SamplingParams(), SAMPLED[3]]
    got = _run_engine(params, PROMPTS, sps, max_slots=4, max_len=32,
                      page_size=8)
    ref = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                              max_len=32, sampling=sps)
    assert got == ref
    with pytest.raises(ValueError, match="entries"):
        sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                            max_len=32, sampling=sps[:2])


def test_seed_stream_invariant_across_retrace_buckets(params):
    """Different max_slots / page_size force different pow2 lane buckets
    (and different padded-lane counts); the fold-in streams must not see
    any of it."""
    a = _run_engine(params, PROMPTS, SAMPLED, max_slots=4, max_len=32,
                    page_size=16)
    b = _run_engine(params, PROMPTS, SAMPLED, max_slots=2, max_len=32,
                    page_size=4)
    assert a == b


def test_seed_stream_invariant_under_preemption(params):
    """A pool too small for both requests forces preempt + re-prefill;
    position-keyed draws replay the identical tokens, so the run matches
    the never-preempted oracle."""
    prompts = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13]]
    sps = [SamplingParams(temperature=1.1, top_p=0.9, seed=5),
           SamplingParams(temperature=0.7, top_k=8, seed=6)]
    eng = ServeEngine(params, CFG, max_slots=2, max_len=24, page_size=8,
                      num_pages=5)
    for p, sp in zip(prompts, sps):
        eng.submit(p, max_new_tokens=12, sampling=sp)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    ref = sequential_generate(params, CFG, prompts, max_new_tokens=12,
                              max_len=24, sampling=sps)
    assert got == ref


def test_same_seed_same_prompt_reproduces(params):
    """Two requests sharing seed AND prompt draw identical tokens —
    reproducibility is the contract; distinct seeds diverge."""
    sps = [SamplingParams(temperature=1.0, seed=9),
           SamplingParams(temperature=1.0, seed=9),
           SamplingParams(temperature=1.0, seed=10)]
    got = _run_engine(params, [[1, 2, 3]] * 3, sps, max_slots=3,
                      max_len=32, page_size=8)
    assert got[0] == got[1]
    assert got[0] != got[2]


def test_eos_stops_sampled_requests(params):
    """The _check_done stop rules apply to sampled tokens too: force an
    unavoidable eos by sampling from a single-token support."""
    sps = [SamplingParams(temperature=1.0, top_k=1, seed=0)]
    ref = sequential_generate(params, CFG, [PROMPTS[0]],
                              max_new_tokens=8, max_len=32,
                              sampling=sps)
    eos = ref[0][2]                          # stop at the 3rd token
    got = _run_engine(params, [PROMPTS[0]], sps, max_new=8, max_slots=2,
                      max_len=32, page_size=8, eos_id=eos)
    seq = sequential_generate(params, CFG, [PROMPTS[0]],
                              max_new_tokens=8, max_len=32, eos_id=eos,
                              sampling=sps)
    assert got == seq
    assert got[0][-1] == eos and len(got[0]) == 3

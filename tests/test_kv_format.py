"""Compressed KV pools + EngineConfig: the format contracts end to end.

Bottom-up, matching the PR's layering:

1. ``core.kv_quant`` round-trip contracts: per-format error bounds, the
   sc residual's pow2 re-scale identity (``alpha_r * 2**SC_SHIFT ==
   alpha_c``, residual never clips), exact-zero round-trips (the trash
   page / unwritten tail must dequantize to 0), format inference from
   pool keys.
2. ``kernels/ref.py``: gather commutes with dequant (bit-exact), and the
   dequant-fused reference equals running the fp reference over
   materialized dequantized pools — bit-exact, so every downstream
   theorem about the fp path transfers to the compressed paths.
3. ``kernels/paged_attention.py``: the fused-dequant Pallas kernels
   (interpret mode) match the reference within the same float tolerance
   as the fp kernels, decode and prefill, int8 and sc.
4. Accuracy vs fp: the attention output of a compressed cache stays
   within the softmax-Lipschitz bound derived from the per-value
   round-trip bounds.
5. ``EngineConfig``: every ``validate()`` rule raises (parametrized over
   the full rule list), ``from_config`` == the kwargs shim token for
   token, and the engine rejects invalid configs through both paths.
6. The serving differential: batched engine(kv_format=X) == B=1 paged
   sequential oracle, BIT-exact within each format — int8 under qat,
   sc under sc_int.
7. Capacity accounting: ``kv_page_bytes`` / ``slots_per_gib`` per
   format, including the acceptance gate int8 >= 2x fp slots at the
   bench shape and unchanged page_size.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.kv_quant import (INT8_BSL, KV_FORMATS, SC_COARSE_BSL,
                                 SC_SHIFT, check_kv_format, kv_dequant,
                                 kv_error_bound, kv_format_of, kv_quant)
from repro.core.residual import pow2_exponent
from repro.kernels import dispatch, ref
from repro.kernels.paged_attention import (paged_attn_decode_pallas,
                                           paged_attn_prefill_pallas)
from repro.models import init_params
from repro.serving import (EngineConfig, ServeEngine, kv_page_bytes,
                           sequential_generate, slots_per_gib)
from repro.serving.paging import pages_needed

COMPRESSED = [f for f in KV_FORMATS if f != "fp"]


def _rand(seed, shape, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# 1. core round-trip contracts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", COMPRESSED)
def test_roundtrip_within_error_bound(fmt):
    x = _rand(0, (5, 7, 3, 16), scale=2.5)
    qd = kv_quant(x, fmt)
    back = kv_dequant(qd["q"], qd.get("scale"), qd.get("resid"), fmt=fmt)
    bound = kv_error_bound(qd["scale"], fmt)[..., None]
    err = jnp.abs(back - x)
    assert np.all(np.asarray(err) <= np.asarray(bound) * (1 + 1e-6)), \
        float(jnp.max(err - bound))


def test_fp_roundtrip_is_identity():
    x = _rand(1, (3, 4, 8))
    qd = kv_quant(x, "fp")
    assert qd.keys() == {"q"}
    np.testing.assert_array_equal(np.asarray(kv_dequant(qd["q"], fmt="fp")),
                                  np.asarray(x))
    assert float(jnp.max(kv_error_bound(jnp.ones((3,)), "fp"))) == 0.0


def test_sc_residual_pow2_contract():
    """The residual scale is EXACTLY alpha_c * 2**-SC_SHIFT (the pow2
    re-scaling block's contract), and the residual never clips: the
    coarse quantizer leaves |r| <= alpha_c/2 == (BSL/2) * alpha_r."""
    x = _rand(2, (4, 6, 2, 16), scale=3.0)
    qd = kv_quant(x, "sc")
    alpha_c = np.asarray(qd["scale"])
    alpha_r = alpha_c * 2.0 ** -SC_SHIFT
    # every (position, head) scale pair sits at the exact pow2 ratio
    exps = {pow2_exponent(ar, ac)
            for ar, ac in zip(alpha_r.ravel(), alpha_c.ravel())}
    assert exps == {SC_SHIFT}
    # residual levels use the full +-BSL/2 range but never exceed it
    resid = np.asarray(qd["resid"])
    assert np.abs(resid).max() <= SC_COARSE_BSL // 2
    r = np.asarray(x) - alpha_c[..., None] * np.asarray(qd["q"],
                                                        np.float32)
    assert np.all(np.abs(r) <= alpha_c[..., None] / 2 * (1 + 1e-6))


@pytest.mark.parametrize("fmt", KV_FORMATS)
def test_zero_roundtrips_exactly(fmt):
    """All-zero vectors (trash page, unwritten positions) must quantize
    to all-zero codes AND scales and dequantize back to exact 0 — this
    is what makes zero-initialized compressed pools safe."""
    x = jnp.zeros((2, 4, 3, 8), jnp.float32)
    qd = kv_quant(x, fmt)
    assert float(jnp.max(jnp.abs(qd["q"].astype(jnp.float32)))) == 0.0
    back = kv_dequant(qd["q"], qd.get("scale"), qd.get("resid"), fmt=fmt)
    np.testing.assert_array_equal(np.asarray(back), np.zeros_like(x))
    # and the pool-initialization path: zero codes + zero scales
    if fmt != "fp":
        z = kv_dequant(jnp.zeros((4, 8), jnp.int8), jnp.zeros((4,)),
                       jnp.zeros((4, 8), jnp.int8) if fmt == "sc" else None,
                       fmt=fmt)
        np.testing.assert_array_equal(np.asarray(z), np.zeros((4, 8)))


def test_format_inference_and_checks():
    assert kv_format_of({"k_pages": 0}) == "fp"
    assert kv_format_of({"k_pages": 0, "k_scale": 0}) == "int8"
    assert kv_format_of({"k_pages": 0, "k_scale": 0, "k_resid": 0}) == "sc"
    for fmt in KV_FORMATS:
        assert check_kv_format(fmt) == fmt
    with pytest.raises(ValueError, match="kv_format"):
        check_kv_format("fp16")
    with pytest.raises(ValueError):
        kv_quant(jnp.zeros((2, 4)), "nf4")


# ---------------------------------------------------------------------------
# 2. reference layer: dequant commutes with gather
# ---------------------------------------------------------------------------

def _pools(seed, S, Hkv, D, page, maxp, fmt):
    """Quantized pools + tables, allocator-style (page 0 = trash)."""
    rng = np.random.default_rng(seed)
    n = S * maxp + 1
    kf = jnp.asarray(rng.standard_normal((n, page, Hkv, D)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((n, page, Hkv, D)), jnp.float32)
    kq, vq = kv_quant(kf, fmt), kv_quant(vf, fmt)
    aux = {}
    if fmt != "fp":
        aux = {"k_scale": kq["scale"], "v_scale": vq["scale"]}
        if fmt == "sc":
            aux |= {"k_resid": kq["resid"], "v_resid": vq["resid"]}
    tables = np.zeros((S, maxp), np.int32)
    for s in range(S):
        tables[s] = 1 + s * maxp + rng.permutation(maxp)
    return rng, kq["q"], vq["q"], jnp.asarray(tables), aux


def _dequant_pool(pages, aux, side, fmt):
    return kv_dequant(pages, aux.get(f"{side}_scale"),
                      aux.get(f"{side}_resid"), fmt=fmt)


@pytest.mark.parametrize("fmt", COMPRESSED)
def test_gather_dequant_commutes(fmt):
    _, kp, _, tables, aux = _pools(3, 3, 2, 16, 8, 4, fmt)
    fused = ref.gather_pages_dequant(kp, tables, kv_format=fmt,
                                     scale=aux["k_scale"],
                                     resid=aux.get("k_resid"))
    first = ref.gather_pages(_dequant_pool(kp, aux, "k", fmt), tables)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(first))


@pytest.mark.parametrize("fmt", COMPRESSED)
def test_ref_fused_dequant_bitexact_decode(fmt):
    """The in-gather dequant is BIT-identical to materializing fp pools
    and running the fp reference — the fp differential theorems transfer
    wholesale to the compressed formats."""
    rng, kp, vp, tables, aux = _pools(5, 3, 2, 16, 8, 4, fmt)
    q = jnp.asarray(rng.standard_normal((3, 2, 2, 16)), jnp.float32)
    lengths = jnp.asarray([5, 17, 31], jnp.int32)
    fused = ref.paged_attn_decode_ref(q, kp, vp, tables, lengths,
                                      kv_format=fmt, kv_aux=aux)
    first = ref.paged_attn_decode_ref(q, _dequant_pool(kp, aux, "k", fmt),
                                      _dequant_pool(vp, aux, "v", fmt),
                                      tables, lengths)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(first))


@pytest.mark.parametrize("fmt", COMPRESSED)
def test_ref_fused_dequant_bitexact_prefill(fmt):
    rng, kp, vp, tables, aux = _pools(7, 2, 2, 16, 8, 5, fmt)
    q = jnp.asarray(rng.standard_normal((2, 16, 2, 2, 16)), jnp.float32)
    fused = ref.paged_attn_prefill_ref(q, kp, vp, tables, 16,
                                       kv_format=fmt, kv_aux=aux)
    first = ref.paged_attn_prefill_ref(q, _dequant_pool(kp, aux, "k", fmt),
                                       _dequant_pool(vp, aux, "v", fmt),
                                       tables, 16)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(first))


# ---------------------------------------------------------------------------
# 3. fused-dequant Pallas kernels vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", COMPRESSED)
@pytest.mark.parametrize("num_splits", [1, 2])
def test_decode_kernel_vs_reference_compressed(fmt, num_splits):
    S, Hkv, G, D, page, maxp = 3, 2, 2, 16, 8, 4
    rng, kp, vp, tables, aux = _pools(S * D, S, Hkv, D, page, maxp, fmt)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray(rng.integers(0, maxp * page, S), jnp.int32)
    got = paged_attn_decode_pallas(q, kp, vp, tables, lengths,
                                   num_splits=num_splits, interpret=True,
                                   kv_format=fmt, **aux)
    want = ref.paged_attn_decode_ref(q, kp, vp, tables, lengths,
                                     kv_format=fmt, kv_aux=aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("fmt", COMPRESSED)
@pytest.mark.parametrize("block_q", [8, 5])
def test_prefill_kernel_vs_reference_compressed(fmt, block_q):
    G, C, Hkv, Gq, D, page, start = 2, 16, 2, 2, 16, 8, 16
    maxp = (start + C) // page + 1
    rng, kp, vp, tables, aux = _pools(G * C, G, Hkv, D, page, maxp, fmt)
    q = jnp.asarray(rng.standard_normal((G, C, Hkv, Gq, D)), jnp.float32)
    got = paged_attn_prefill_pallas(q, kp, vp, tables, start=start,
                                    block_q=block_q, interpret=True,
                                    kv_format=fmt, **aux)
    want = ref.paged_attn_prefill_ref(q, kp, vp, tables, start,
                                      kv_format=fmt, kv_aux=aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("fmt", COMPRESSED)
def test_dispatch_threads_kv_aux(fmt):
    """dispatch.paged_attn_decode forwards kv_format/kv_aux to both
    backends; kernel path == its own direct call, bit for bit."""
    S, Hkv, G, D, page, maxp = 3, 2, 2, 16, 8, 3
    rng, kp, vp, tables, aux = _pools(23, S, Hkv, D, page, maxp, fmt)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray([3, 11, 20], jnp.int32)
    via = dispatch.paged_attn_decode(q, kp, vp, tables, lengths,
                                     backend="pallas-interpret",
                                     kv_format=fmt, kv_aux=aux)
    direct = paged_attn_decode_pallas(q, kp, vp, tables, lengths,
                                      interpret=True, kv_format=fmt, **aux)
    np.testing.assert_array_equal(np.asarray(via), np.asarray(direct))


# ---------------------------------------------------------------------------
# 4. accuracy vs fp: the softmax-Lipschitz bound
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", COMPRESSED)
def test_attention_output_within_lipschitz_bound(fmt):
    """|out_fmt - out_fp| <= eps_v + vmax * (e^{2d} - 1) with
    d = ||q||_1 * max(eps_k) / sqrt(D): perturbing every key by at most
    eps_k moves each logit by at most ||q||_1 * eps_k / sqrt(D), the
    softmax weights by a factor in [e^{-2d}, e^{2d}], and the convex
    V-combination by at most vmax * (e^{2d} - 1); the value round-trip
    adds eps_v directly."""
    S, Hkv, G, D, page, maxp = 2, 2, 2, 16, 8, 3
    rng = np.random.default_rng(31)
    n = S * maxp + 1
    kf = jnp.asarray(rng.standard_normal((n, page, Hkv, D)) * 0.5,
                     jnp.float32)
    vf = jnp.asarray(rng.standard_normal((n, page, Hkv, D)) * 0.5,
                     jnp.float32)
    tables = np.zeros((S, maxp), np.int32)
    for s in range(S):
        tables[s] = 1 + s * maxp + rng.permutation(maxp)
    tables = jnp.asarray(tables)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)) * 0.5, jnp.float32)
    lengths = jnp.asarray([11, 23], jnp.int32)

    out_fp = ref.paged_attn_decode_ref(q, kf, vf, tables, lengths)
    kq, vq = kv_quant(kf, fmt), kv_quant(vf, fmt)
    aux = {"k_scale": kq["scale"], "v_scale": vq["scale"]}
    if fmt == "sc":
        aux |= {"k_resid": kq["resid"], "v_resid": vq["resid"]}
    out_q = ref.paged_attn_decode_ref(q, kq["q"], vq["q"], tables,
                                      lengths, kv_format=fmt, kv_aux=aux)

    eps_k = float(jnp.max(kv_error_bound(kq["scale"], fmt)))
    eps_v = float(jnp.max(kv_error_bound(vq["scale"], fmt)))
    vmax = float(jnp.max(jnp.abs(vf)))
    q1 = float(jnp.max(jnp.sum(jnp.abs(q), axis=-1)))
    d = q1 * eps_k / math.sqrt(D)
    bound = eps_v + vmax * (math.exp(2 * d) - 1)
    diff = float(jnp.max(jnp.abs(out_q - out_fp)))
    assert diff <= bound, (diff, bound)
    # the bound is meaningfully tight: the sc path (8 extra code bits)
    # must beat int8's worst case
    if fmt == "sc":
        assert eps_k < 2.0 / INT8_BSL


# ---------------------------------------------------------------------------
# 5. EngineConfig: the single construction path
# ---------------------------------------------------------------------------

SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
             vocab_pad_multiple=32, dtype="float32", attn_q_chunk=8)
CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]


def test_engine_config_defaults_validate():
    c = EngineConfig()
    assert c.validate() is c
    assert c.kv_format == "fp" and c.datapath == "qat"


@pytest.mark.parametrize("changes,match", [
    (dict(max_slots=0), "max_slots"),
    (dict(max_len=1), "max_len"),
    (dict(page_size=7), "power of two"),
    (dict(page_size=0), "power of two"),
    (dict(num_pages=1), "trash page"),
    (dict(prefill_chunk=0), "prefill_chunk"),
    (dict(datapath="fp8"), "datapath"),
    (dict(kv_format="nf4"), "kv_format"),
    (dict(kv_format="sc", datapath="qat"), "SC"),
    (dict(bsn_backend="verilog"), "bsn_backend"),
    (dict(attn_backend="verilog"), "attn_backend"),
    (dict(prefill_mode="streaming"), "prefill_mode"),
])
def test_engine_config_rejects(changes, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**changes).validate()


def test_engine_config_mesh_needs_reference_attention():
    from repro.launch.mesh import make_serving_mesh, serving_rules
    rules = serving_rules(make_serving_mesh(model_parallel=1,
                                            data_parallel=1))
    EngineConfig(mesh_rules=rules).validate()                 # auto: fine
    EngineConfig(mesh_rules=rules,
                 attn_backend="reference").validate()         # pinned ref
    with pytest.raises(ValueError, match="mesh"):
        EngineConfig(mesh_rules=rules,
                     attn_backend="pallas-interpret").validate()


def test_engine_config_replace():
    c = EngineConfig().replace(kv_format="int8", page_size=8)
    assert (c.kv_format, c.page_size) == ("int8", 8)
    assert EngineConfig().kv_format == "fp"                   # frozen


def test_engine_validates_through_both_paths():
    params = init_params(jax.random.key(0), CFG)
    with pytest.raises(ValueError, match="power of two"):
        ServeEngine(params, CFG, page_size=7)
    with pytest.raises(ValueError, match="kv_format"):
        ServeEngine(params, CFG, kv_format="nf4")
    with pytest.raises(ValueError, match="SC"):
        ServeEngine.from_config(params, CFG,
                                EngineConfig(kv_format="sc"))


def test_from_config_equals_kwarg_shim():
    """The kwargs shim and from_config are the same engine: identical
    tokens and identical resolved EngineConfig."""
    params = init_params(jax.random.key(0), CFG)

    def run(eng):
        for p in PROMPTS:
            eng.submit(p, max_new_tokens=4)
        done = eng.run_to_completion()
        return [r.generated for r in sorted(done, key=lambda r: r.rid)]

    kw = dict(max_slots=2, max_len=32, page_size=8, kv_format="int8")
    a = ServeEngine(params, CFG, **kw)
    b = ServeEngine.from_config(params, CFG, EngineConfig(**kw))
    assert a.config == b.config
    assert run(a) == run(b)


def test_submit_rejects_nonpositive_max_new_tokens():
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(params, CFG, max_slots=2, max_len=32, page_size=8)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit([1, 2], max_new_tokens=bad)
    eng.submit([1, 2], max_new_tokens=1)                      # boundary ok


# ---------------------------------------------------------------------------
# 6. the serving differential per format
# ---------------------------------------------------------------------------

def _engine_tokens(params, config, max_new=5):
    eng = ServeEngine.from_config(params, CFG, config)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_to_completion()
    assert len(done) == len(PROMPTS)
    return [r.generated for r in sorted(done, key=lambda r: r.rid)]


@pytest.mark.parametrize("fmt,datapath", [("int8", "qat"),
                                          ("int8", "sc_int"),
                                          ("sc", "sc_int")])
def test_engine_batched_equals_sequential_compressed(fmt, datapath):
    """The acceptance differential for the compressed pools: the batched
    continuous-batching engine produces EXACTLY the tokens of the B=1
    paged sequential oracle in the same format (per-position scales make
    quantization order-independent), at a DIFFERENT oracle page size —
    the codes are page-layout-invariant."""
    params = init_params(jax.random.key(0), CFG)
    got = _engine_tokens(params, EngineConfig(
        max_slots=2, max_len=64, page_size=16, prefill_chunk=8,
        datapath=datapath, kv_format=fmt))
    want = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                               max_len=64, datapath=datapath,
                               kv_format=fmt, page_size=8)
    assert got == want, (fmt, datapath)


def test_compressed_formats_actually_change_tokens():
    """Sanity that the differential above isn't vacuous: at this tiny
    scale the int8 cache round-trip perturbs logits enough to move some
    argmax — if all formats agreed everywhere, the format tests would
    not be exercising distinct numerics."""
    params = init_params(jax.random.key(0), CFG)
    fp = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                             max_len=64)
    i8 = sequential_generate(params, CFG, PROMPTS, max_new_tokens=5,
                             max_len=64, kv_format="int8")
    assert fp != i8


# ---------------------------------------------------------------------------
# 7. capacity accounting
# ---------------------------------------------------------------------------

def test_kv_page_bytes_per_format():
    # bench shape: page=16, Hkv=2, Dh=16, f32
    assert kv_page_bytes(16, 2, 16, "fp") == 4096
    assert kv_page_bytes(16, 2, 16, "int8") == 1280
    assert kv_page_bytes(16, 2, 16, "sc") == 2304
    with pytest.raises(ValueError):
        kv_page_bytes(16, 2, 16, "nf4")


def test_int8_at_least_doubles_slots_per_gib():
    """The acceptance gate: >= 2x full-length request slots per GiB for
    int8 vs fp at unchanged page_size."""
    args = (256, 16, 2, 16)
    ratio = slots_per_gib(*args, "int8") / slots_per_gib(*args, "fp")
    assert ratio >= 2.0, ratio
    # sc trades some of that back for the residual pool but still wins
    assert slots_per_gib(*args, "sc") > slots_per_gib(*args, "fp")


def test_slots_per_gib_accounting():
    got = slots_per_gib(256, 16, 2, 16, "fp", n_layers=2)
    want = (1 << 30) / (pages_needed(256, 16) * 4096 * 2)
    assert got == pytest.approx(want)

"""Injection suite for the static Pallas kernel auditor.

Strategy: build small hand-written :class:`LaunchPlan`s with one defect
each — an index map that runs one page past the table under the
worst-case scalar fill, a scratch allocation over the VMEM budget, a
revisited output with no declared accumulator / no ``pl.when`` guard /
a ``parallel`` revisit axis — and require that *exactly* the targeted
pass fires (the other three stay green).  Then the shipped registry
(every kernel x kv_format x autotune sweep shape) must audit clean.

The injected plans are never executed, which is the point: the auditor
must catch these from geometry alone.
"""

from __future__ import annotations

import jax.numpy as jnp
import pytest

from repro.analysis.kernel_audit import (audit_registry, run_plan_audits,
                                         scalar_sets)
from repro.analysis.lint import hygiene_repo, hygiene_scan
from repro.kernels.dispatch import KERNEL_REGISTRY
from repro.kernels.plan import (BlockOperand, LaunchPlan, ScalarOperand,
                                estimate_vmem)

# ---------------------------------------------------------------------------
# fixtures: a clean baseline plan and one-defect mutants
# ---------------------------------------------------------------------------

PAGES, PAGE, ROWS, D = 9, 16, 64, 32


def _kernel_with_when(x_ref, o_ref):          # body is never traced
    import jax.experimental.pallas as pl      # pragma: no cover
    pl.when
    o_ref[...] = x_ref[...]


def _kernel_plain(x_ref, o_ref):              # pragma: no cover
    o_ref[...] = x_ref[...]


def _base_plan(**over):
    """A paged-gather plan shaped like the real decode kernels: a page
    table scalar selects which KV page each grid step streams."""
    kw = dict(
        name="toy_paged",
        grid=(4,),
        scalars=(ScalarOperand("table", (4,), jnp.int32,
                               max_value=PAGES - 1),),
        inputs=(BlockOperand("pages", (PAGES, PAGE, D), jnp.float32,
                             (1, PAGE, D),
                             lambda p, tbl: (tbl[p], 0, 0)),),
        outputs=(BlockOperand("o", (4, PAGE, D), jnp.float32,
                              (1, PAGE, D), lambda p, tbl: (p, 0, 0)),),
        scratch=(),
        kernel=_kernel_plain,
    )
    kw.update(over)
    return LaunchPlan(**kw)


def _passes(plan):
    res = run_plan_audits(plan, "inj")
    return {r.passname: r for r in res}


def _only_fails(plan, passname):
    """Assert exactly ``passname`` fires; return its violations."""
    byname = _passes(plan)
    assert not byname[passname].ok, \
        f"{passname} should have fired: {byname[passname].to_dict()}"
    for other, r in byname.items():
        if other != passname:
            assert r.ok, (f"{other} fired collaterally: "
                          f"{[v.message for v in r.violations]}")
    return byname[passname].violations


# ---------------------------------------------------------------------------
# clean baseline
# ---------------------------------------------------------------------------

def test_clean_plan_passes_all_four():
    byname = _passes(_base_plan())
    assert all(r.ok for r in byname.values()), \
        {k: [v.message for v in r.violations] for k, r in byname.items()}
    assert set(byname) == {"bounds", "vmem", "revisit", "grid"}


def test_scalar_sets_cover_extremes_and_declared_values():
    plan = _base_plan(scalars=(
        ScalarOperand("table", (4,), jnp.int32, max_value=PAGES - 1),
        ScalarOperand("len", (1,), jnp.int32, max_value=63,
                      values=(15, 16, 17), kernel_only=True),))
    fills = {(int(s["table"].flat[0]), int(s["len"].flat[0]))
             for s in scalar_sets(plan)}
    assert fills == {(t, l) for t in (0, PAGES - 1)
                     for l in (0, 15, 16, 17, 63)}


# ---------------------------------------------------------------------------
# pass 1: bounds
# ---------------------------------------------------------------------------

def test_bounds_catches_off_by_one_past_last_page():
    # the classic: indexing tbl[p] + 1 walks one page past the table's
    # worst-case (num_pages - 1) entry — only visible at the scalar
    # extreme, which is exactly what the fill model pins
    bad = _base_plan(inputs=(
        BlockOperand("pages", (PAGES, PAGE, D), jnp.float32, (1, PAGE, D),
                     lambda p, tbl: (tbl[p] + 1, 0, 0)),))
    vios = _only_fails(bad, "bounds")
    assert any("pages" in v.message and "out" not in v.message.split()[0]
               for v in vios)
    # in-bounds at fill 0: the violation must cite the max fill
    assert any(str(PAGES) in v.message for v in vios)


def test_bounds_catches_grid_overrun_without_scalars():
    bad = _base_plan(
        scalars=(),
        inputs=(BlockOperand("x", (ROWS, D), jnp.float32, (16, D),
                             lambda i: (i + 1, 0)),),
        outputs=(BlockOperand("o", (4, PAGE, D), jnp.float32,
                              (1, PAGE, D), lambda i: (i, 0, 0)),))
    vios = _only_fails(bad, "bounds")
    assert any("x" in v.message for v in vios)


def test_bounds_ok_for_partial_final_block():
    # 65 rows / block 16 -> 5 blocks, the last partial: still legal
    ok = _base_plan(
        scalars=(),
        inputs=(BlockOperand("x", (65, D), jnp.float32, (16, D),
                             lambda i: (i, 0)),),
        outputs=(BlockOperand("o", (4, PAGE, D), jnp.float32,
                              (1, PAGE, D), lambda i: (i, 0, 0)),))
    assert _passes(ok)["bounds"].ok


# ---------------------------------------------------------------------------
# pass 2: vmem
# ---------------------------------------------------------------------------

def test_vmem_catches_scratch_over_budget():
    bad = _base_plan(scratch=(((2048, 2048), jnp.float32),))  # 16 MiB
    vios = _only_fails(bad, "vmem")
    assert "exceeds budget" in vios[0].message
    assert estimate_vmem(bad) > 8 * 2 ** 20


def test_vmem_budget_is_configurable():
    plan = _base_plan()
    res = run_plan_audits(plan, "inj", vmem_budget=16)
    byname = {r.passname: r for r in res}
    assert not byname["vmem"].ok                # tiny budget trips it
    assert byname["bounds"].ok and byname["grid"].ok


# ---------------------------------------------------------------------------
# pass 3: revisit / race
# ---------------------------------------------------------------------------

def _revisit_plan(**over):
    """Grid (2, 3): the t axis folds onto one output block."""
    kw = dict(
        name="toy_accum",
        grid=(2, 3),
        scalars=(),
        inputs=(BlockOperand("x", (ROWS, 3 * D), jnp.float32, (32, D),
                             lambda i, t: (i, t)),),
        outputs=(BlockOperand("o", (ROWS, D), jnp.float32, (32, D),
                              lambda i, t: (i, 0)),),
        scratch=(),
        kernel=_kernel_with_when,
        accumulate={"o": "when-init-accumulate"},
        dimension_semantics=("parallel", "arbitrary"),
    )
    kw.update(over)
    return LaunchPlan(**kw)


def test_revisit_clean_accumulator_passes():
    byname = _passes(_revisit_plan())
    assert byname["revisit"].ok, \
        [v.message for v in byname["revisit"].violations]


def test_revisit_catches_undeclared_accumulation():
    vios = _only_fails(_revisit_plan(accumulate={}), "revisit")
    assert "last-write-wins" in vios[0].message


def test_revisit_catches_missing_pl_when_guard():
    vios = _only_fails(_revisit_plan(kernel=_kernel_plain), "revisit")
    assert "pl.when" in vios[0].message


def test_revisit_catches_parallel_race_axis():
    vios = _only_fails(
        _revisit_plan(dimension_semantics=("parallel", "parallel")),
        "revisit")
    assert "race" in vios[0].message


def test_revisit_catches_stale_declaration():
    # output visited once per grid step — declaring an accumulator lies
    bad = _revisit_plan(
        outputs=(BlockOperand("o", (ROWS, 3 * D), jnp.float32, (32, D),
                              lambda i, t: (i, t)),))
    vios = _only_fails(bad, "revisit")
    assert "never revisited" in vios[0].message


# ---------------------------------------------------------------------------
# pass 4: grid / arity
# ---------------------------------------------------------------------------

def test_grid_catches_index_map_arity_mismatch():
    bad = _base_plan(inputs=(
        BlockOperand("pages", (PAGES, PAGE, D), jnp.float32, (1, PAGE, D),
                     lambda p: (p, 0, 0)),))       # forgot the table arg
    vios = _only_fails(bad, "grid")
    assert "takes 1 args" in vios[0].message
    # bounds must note it skipped the operand, not crash on it
    assert any("arity" in n for n in _passes(bad)["bounds"].notes)


def test_grid_catches_unreferenced_scalar():
    bad = _base_plan(
        inputs=(BlockOperand("pages", (PAGES, PAGE, D), jnp.float32,
                             (1, PAGE, D), lambda p, tbl: (p, 0, 0)),))
    vios = _only_fails(bad, "grid")
    assert "never referenced" in vios[0].message


def test_grid_allows_kernel_only_scalar():
    ok = _base_plan(
        scalars=(ScalarOperand("table", (4,), jnp.int32,
                               max_value=PAGES - 1),
                 ScalarOperand("lengths", (4,), jnp.int32, max_value=63,
                               kernel_only=True)),
        inputs=(BlockOperand("pages", (PAGES, PAGE, D), jnp.float32,
                             (1, PAGE, D),
                             lambda p, tbl, ln: (tbl[p], 0, 0)),),
        outputs=(BlockOperand("o", (4, PAGE, D), jnp.float32,
                              (1, PAGE, D),
                              lambda p, tbl, ln: (p, 0, 0)),))
    assert _passes(ok)["grid"].ok


def test_grid_catches_block_rank_and_size():
    bad = _base_plan(outputs=(
        BlockOperand("o", (4, PAGE, D), jnp.float32, (1, PAGE, 2 * D),
                     lambda p, tbl: (p, 0, 0)),))
    vios = _only_fails(bad, "grid")
    assert "block dim" in vios[0].message


# ---------------------------------------------------------------------------
# the shipped fleet
# ---------------------------------------------------------------------------

def test_registry_covers_every_kernel_and_format():
    rep = audit_registry()
    names = {l.split("/")[0] for l in rep["kernels"]}
    assert names == set(KERNEL_REGISTRY)
    fmts = {l.split("/")[1] for l in rep["kernels"]
            if l.startswith("paged_attn_decode/")}
    assert fmts == {"fp", "int8", "sc"}


def test_registry_audits_clean():
    rep = audit_registry()
    bad = {l: [v for p in c["passes"] for v in p["violations"]]
           for l, c in rep["kernels"].items() if not c["ok"]}
    assert rep["ok"] and not bad, bad


def test_registry_reports_vmem_within_budget():
    rep = audit_registry()
    for label, cell in rep["kernels"].items():
        assert 0 < cell["vmem_est"] <= rep["budget_bytes"], \
            (label, cell["vmem_est"])


# ---------------------------------------------------------------------------
# ANALYSIS.json schema stamp
# ---------------------------------------------------------------------------

def _analyze_mod():
    import importlib.util
    from pathlib import Path
    path = Path(__file__).resolve().parents[1] / "tools" / "analyze.py"
    spec = importlib.util.spec_from_file_location("_analyze_tool", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_schema_stamp_round_trip(tmp_path):
    import json
    m = _analyze_mod()
    p = tmp_path / "ANALYSIS.json"
    p.write_text(json.dumps({"schema": m.ANALYSIS_SCHEMA}))
    assert m.check_artifact_schema(p) == m.ANALYSIS_SCHEMA
    p.write_text(json.dumps({"cells": {}}))     # pre-stamp artifact
    assert m.check_artifact_schema(p) == 1
    assert m.check_artifact_schema(tmp_path / "missing.json") is None


def test_unknown_schema_fails_loudly(tmp_path):
    import json
    m = _analyze_mod()
    p = tmp_path / "ANALYSIS.json"
    p.write_text(json.dumps({"schema": m.ANALYSIS_SCHEMA + 1}))
    with pytest.raises(SystemExit, match="unknown ANALYSIS.json schema"):
        m.check_artifact_schema(p)


# ---------------------------------------------------------------------------
# hygiene (satellite: no tracked bytecode)
# ---------------------------------------------------------------------------

def test_hygiene_scan_flags_bytecode_paths():
    vios = hygiene_scan(["src/repro/a.py",
                         "src/repro/__pycache__/a.cpython-310.pyc",
                         "tools/b.pyc", "README.md"])
    assert sorted(v.file for v in vios) == \
        ["src/repro/__pycache__/a.cpython-310.pyc", "tools/b.pyc"]
    assert all(v.rule == "hygiene" for v in vios)


def test_repo_tracks_no_bytecode():
    assert hygiene_repo() == []

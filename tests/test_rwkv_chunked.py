"""Chunked (GLA-form) wkv == token-recurrence wkv, exactly (§Perf cell B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.models.rwkv6 import _wkv_chunked, _wkv_scan


def _inputs(seed, B=2, S=64, H=2, D=8, w_strength=1.0):
    ks = jax.random.split(jax.random.key(seed), 5)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    # decays in (0, 1): rwkv6's exp(-exp(.)) form
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D))
                         * w_strength))
    u = jax.random.normal(ks[4], (H, D)) * 0.3
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    return r, k, v, w, u, s0


@given(st.integers(0, 100), st.sampled_from([8, 16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_chunked_equals_scan(seed, chunk):
    r, k, v, w, u, s0 = _inputs(seed)
    y1, s1 = _wkv_scan(r, k, v, w, u, s0)
    y2, s2 = _wkv_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_chunked_stable_under_extreme_decay():
    """Strong decay (w -> 0) must not overflow: all exponents stay <= 0."""
    r, k, v, w, u, s0 = _inputs(7, w_strength=3.0)
    w = jnp.minimum(w, 0.01)                 # near-total forgetting
    y2, s2 = _wkv_chunked(r, k, v, w, u, s0, 32)
    assert bool(jnp.all(jnp.isfinite(y2))) and bool(jnp.all(jnp.isfinite(s2)))
    y1, s1 = _wkv_scan(r, k, v, w, u, s0)
    # f32 noise floor: exp() of ~-60 log-decay differences
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=5e-4)


def test_chunked_with_nonzero_initial_state():
    r, k, v, w, u, _ = _inputs(3)
    s0 = jax.random.normal(jax.random.key(9), (2, 2, 8, 8)).astype(
        jnp.float32)
    y1, s1 = _wkv_scan(r, k, v, w, u, s0)
    y2, s2 = _wkv_chunked(r, k, v, w, u, s0, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_model_level_parity():
    """Full rwkv6 forward: chunked config == scan config."""
    from repro.models import forward, init_params, make_dummy_batch
    base = get_arch("rwkv6-7b").scaled(
        n_layers=2, d_model=64, d_ff=128, vocab_size=131, n_heads=4,
        n_kv_heads=4, rwkv_head_dim=16, dtype="float32",
        vocab_pad_multiple=32, attn_q_chunk=8)
    chunked = base.scaled(rwkv_wkv_impl="chunked", rwkv_chunk=8)
    params = init_params(jax.random.key(0), base)
    batch = make_dummy_batch(base, 2, 32, "prefill")
    l1, _, _ = forward(params, batch, base)
    l2, _, _ = forward(params, batch, chunked)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=5e-4, atol=5e-4)

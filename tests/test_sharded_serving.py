"""Mesh-sharded paged serving: the tensor-parallel differential theorem.

The sharded engine (`ServeEngine(mesh_rules=...)`) must be TOKEN-
IDENTICAL to the unsharded engine and to `sequential_generate` — not
approximately equal.  That holds because the serving layout shards
output channels only (column-parallel projections, whole experts per
device, KV pools over KV heads): every norm / quantizer / accumulator
reduction stays device-local, so mesh-on decode produces bit-equal
logits on the qat path and bit-equal integer sums on the sc_int /
sc_int_approx paths (the approximate BSN adder is a per-output-channel
unit — splitting its inputs across chips would change the answer, which
is exactly why no contraction dim is ever sharded).

These tests need a multi-device jax, which must be forced BEFORE jax
initializes: run under ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` (the CI sharded job does; so does the tier-1 subprocess
wrapper ``test_paged_kv.py::test_sharded_serving_subprocess``).  With
fewer devices everything here skips.
"""

import jax
import pytest

from repro.configs import LayerSpec, get_arch
from repro.launch.mesh import make_serving_mesh, serving_rules
from repro.models import init_params
from repro.serving import (SamplingParams, ServeEngine,
                           sequential_generate)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices — set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

# n_kv_heads=4 so the KV page pools actually shard over the 4-way
# "model" axis (2 data x 4 model = the forced 8 devices)
SCALE = dict(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)
ATTN_CFG = get_arch("granite-3-2b").scaled(n_layers=2, **SCALE)
MOE_CFG = get_arch("dbrx-132b").scaled(
    n_layers=2, **SCALE, n_experts=4, n_experts_per_tok=2,
    moe_capacity_factor=2.0)
# the hybrid: mamba (d_inner=128 shards 4-way) + attn + MoE in one
# period — the union of everything the chunked prefill has to carry
JAMBA_CFG = get_arch("jamba-1.5-large-398b").scaled(
    n_layers=8, **SCALE, mamba_d_state=8, n_experts=4,
    n_experts_per_tok=2, moe_capacity_factor=2.0)
RWKV_CFG = get_arch("rwkv6-7b").scaled(n_layers=2, **SCALE,
                                       rwkv_head_dim=16)
PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]


def _rules():
    return serving_rules(make_serving_mesh(model_parallel=4,
                                           data_parallel=2))


def _engine_tokens(params, cfg, datapath, rules, max_new=4,
                   sampling=None, prefill_mode="chunked", **kw):
    eng = ServeEngine(params, cfg, max_slots=2, max_len=32, page_size=8,
                      datapath=datapath, mesh_rules=rules,
                      prefill_mode=prefill_mode, **kw)
    sps = sampling or [None] * len(PROMPTS)
    for p, sp in zip(PROMPTS, sps):
        eng.submit(p, max_new_tokens=max_new, sampling=sp)
    done = eng.run_to_completion()
    assert len(done) == len(PROMPTS)
    return [r.generated for r in sorted(done, key=lambda r: r.rid)]


@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
@pytest.mark.parametrize("cfg", [ATTN_CFG, MOE_CFG], ids=["attn", "moe"])
def test_mesh_on_equals_mesh_off_equals_sequential(cfg, datapath):
    """The acceptance differential: sharded == unsharded == oracle,
    token for token, on an attention config and an MoE config across
    all three datapaths."""
    params = init_params(jax.random.key(0), cfg)
    sharded = _engine_tokens(params, cfg, datapath, _rules())
    local = _engine_tokens(params, cfg, datapath, None)
    ref = sequential_generate(params, cfg, PROMPTS, max_new_tokens=4,
                              max_len=32, datapath=datapath)
    assert sharded == local, (cfg.name, datapath)
    assert local == ref, (cfg.name, datapath)


SAMPLED = [SamplingParams(temperature=0.8, top_p=0.9, seed=11 + i)
           for i in range(len(PROMPTS))]


@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
def test_sampled_mesh_on_equals_mesh_off_equals_sequential(datapath):
    """The seeded third of the acceptance differential: nontrivial
    temperature/top-p draws are token-identical across the mesh-sharded
    engine, the unsharded engine, and the sequential oracle on every
    datapath.  Holds because the sampler's PRNG streams are keyed by
    (seed, position) only and the logit/sample tensors are pinned
    replicated before the categorical draw — the mesh can change neither
    the kept set nor the Gumbel bits."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    sharded = _engine_tokens(params, ATTN_CFG, datapath, _rules(),
                             sampling=SAMPLED)
    local = _engine_tokens(params, ATTN_CFG, datapath, None,
                           sampling=SAMPLED)
    ref = sequential_generate(params, ATTN_CFG, PROMPTS,
                              max_new_tokens=4, max_len=32,
                              datapath=datapath, sampling=SAMPLED)
    assert sharded == local == ref, datapath
    greedy = sequential_generate(params, ATTN_CFG, PROMPTS,
                                 max_new_tokens=4, max_len=32,
                                 datapath=datapath)
    assert sharded != greedy, "sampling degenerated to greedy"


@pytest.mark.parametrize("fmt,datapath", [("int8", "qat"),
                                          ("int8", "sc_int"),
                                          ("sc", "sc_int")])
def test_mesh_on_equals_mesh_off_compressed(fmt, datapath):
    """The compressed pools under the mesh: quantize-on-scatter and the
    dequant-fused reference attention are elementwise per (position,
    head), so sharding the KV-head axis changes nothing — mesh-on ==
    mesh-off == same-format sequential oracle, token for token."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    sharded = _engine_tokens(params, ATTN_CFG, datapath, _rules(),
                             kv_format=fmt)
    local = _engine_tokens(params, ATTN_CFG, datapath, None,
                           kv_format=fmt)
    seq = sequential_generate(params, ATTN_CFG, PROMPTS, max_new_tokens=4,
                              max_len=32, datapath=datapath,
                              kv_format=fmt)
    assert sharded == local, (fmt, datapath)
    assert local == seq, (fmt, datapath)


def test_kv_scale_and_residual_pools_shard_with_the_code_pages():
    """The parallel scale / residual pools carry the SAME KV-head "model"
    axis as the code pages (a scale must live with its head's codes, or
    the fused dequant would gather cross-device)."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    eng = ServeEngine(params, ATTN_CFG, max_slots=2, max_len=32,
                      page_size=8, mesh_rules=_rules(), datapath="sc_int",
                      kv_format="sc")
    entry = eng.cache["periods"]["p0"]
    # codes / residuals: (n_periods, num_pages, page, Hkv, Dh)
    assert entry["k_pages"].sharding.spec[3] == "model"
    assert entry["k_resid"].sharding.spec[3] == "model"
    # scales: (n_periods, num_pages, page, Hkv)
    assert entry["k_scale"].sharding.spec[3] == "model"
    assert entry["v_scale"].sharding.spec[3] == "model"


def test_kv_pools_sharded_over_model_axis():
    """The page pools really shard their KV-head axis (weights-resident
    layout), while host bookkeeping stays device-count-agnostic."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    eng = ServeEngine(params, ATTN_CFG, max_slots=2, max_len=32,
                      page_size=8, mesh_rules=_rules())
    kp = eng.cache["periods"]["p0"]["k_pages"]
    # (n_periods, num_pages, page, Hkv, Dh): Hkv carries "model"
    assert kp.sharding.spec[3] == "model"
    wq = eng.params["periods"]["p0"]["mixer"]["wq"]["w"]
    # (n_periods, d_model, hq*dh): column-parallel -> out dim on "model"
    assert wq.sharding.spec[2] == "model"
    # the allocator never saw the mesh
    assert eng.allocator.num_pages == eng.max_slots * eng.max_pages + 1


def test_uneven_heads_degrade_to_replicated():
    """A KV-head count that doesn't divide the model axis must degrade
    that leaf to replicated (fit_spec), not error."""
    cfg = get_arch("granite-3-2b").scaled(
        n_layers=2, **{**SCALE, "n_kv_heads": 2, "n_heads": 4})
    params = init_params(jax.random.key(0), cfg)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=32, page_size=8,
                      mesh_rules=_rules())          # model axis = 4, Hkv = 2
    kp = eng.cache["periods"]["p0"]["k_pages"]
    assert kp.sharding.spec[3] is None
    for p in PROMPTS[:2]:
        eng.submit(p, max_new_tokens=3)
    done = eng.run_to_completion()
    ref = sequential_generate(params, cfg, PROMPTS[:2], max_new_tokens=3,
                              max_len=32)
    assert [r.generated for r in
            sorted(done, key=lambda r: r.rid)] == ref


@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
def test_recurrent_chunked_mesh_on_equals_mesh_off(datapath):
    """The tentpole's mesh third: the jamba hybrid (mamba + attn + MoE)
    prefills through the batched chunked paged path UNDER the mesh —
    the carried chunk state keeps the paged_cache_specs pins (channel
    axes over "model", constrain_tree), so sharded == unsharded ==
    sequential, token for token, on every datapath."""
    params = init_params(jax.random.key(0), JAMBA_CFG)
    sharded = _engine_tokens(params, JAMBA_CFG, datapath, _rules())
    local = _engine_tokens(params, JAMBA_CFG, datapath, None)
    ref = sequential_generate(params, JAMBA_CFG, PROMPTS,
                              max_new_tokens=4, max_len=32,
                              datapath=datapath)
    assert sharded == local, datapath
    assert local == ref, datapath


def test_recurrent_sampled_mesh_on_equals_mesh_off():
    """Seeded stochastic decode over the chunked recurrent prefill,
    mesh-on vs mesh-off vs oracle (rwkv6: tmix + cmix state rows)."""
    params = init_params(jax.random.key(0), RWKV_CFG)
    sharded = _engine_tokens(params, RWKV_CFG, "qat", _rules(),
                             sampling=SAMPLED)
    local = _engine_tokens(params, RWKV_CFG, "qat", None,
                           sampling=SAMPLED)
    ref = sequential_generate(params, RWKV_CFG, PROMPTS,
                              max_new_tokens=4, max_len=32,
                              sampling=SAMPLED)
    greedy = sequential_generate(params, RWKV_CFG, PROMPTS,
                                 max_new_tokens=4, max_len=32)
    assert sharded == local == ref
    assert sharded != greedy, "sampling degenerated to greedy"


def test_recurrent_exact_oracle_sharded_matches_sequential():
    """prefill_mode="exact" (debug oracle): the per-request exact-length
    prefill's eager scatter runs OUTSIDE the jit — under a mesh its
    output must be re-pinned to the init-time cache layout (or the next
    decode step loses donation and copies the whole cache).  Kept on
    the retired path so the oracle stays trustworthy."""
    params = init_params(jax.random.key(0), RWKV_CFG)
    got = _engine_tokens(params, RWKV_CFG, "qat", _rules(),
                         prefill_mode="exact")
    ref = sequential_generate(params, RWKV_CFG, PROMPTS, max_new_tokens=4,
                              max_len=32)
    assert got == ref


@pytest.mark.parametrize("datapath", ["qat", "sc_int", "sc_int_approx"])
def test_kernel_attention_mesh_on_equals_mesh_off(datapath):
    """The paged-attention kernel third: mesh-on decode (which always
    serves the constrained XLA reference — the kernel is a single-device
    program) is token-identical to the mesh-off engine pinned to the
    interpret-mode Pallas kernel.  This is the cross-arithmetic leg of
    the differential: flash-decoding online-softmax vs gathered full
    softmax, same tokens."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    sharded = _engine_tokens(params, ATTN_CFG, datapath, _rules())
    eng = ServeEngine(params, ATTN_CFG, max_slots=2, max_len=32,
                      page_size=8, datapath=datapath,
                      attn_backend="pallas-interpret")
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=4)
    done = eng.run_to_completion()
    kernel = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    assert sharded == kernel, datapath
    ref = sequential_generate(params, ATTN_CFG, PROMPTS, max_new_tokens=4,
                              max_len=32, datapath=datapath)
    assert kernel == ref, datapath


def test_mesh_engine_rejects_pinned_pallas_attention():
    """Pinning a pallas attention backend under mesh rules is a
    contradiction (the kernel is single-device) and must fail loudly,
    not silently serve something else."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    with pytest.raises(ValueError):
        ServeEngine(params, ATTN_CFG, max_slots=2, max_len=32,
                    page_size=8, mesh_rules=_rules(),
                    attn_backend="pallas-interpret")


def test_degenerate_mesh_equals_no_mesh():
    """A (1, 1) mesh is behaviorally identical to mesh_rules=None."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    rules = serving_rules(make_serving_mesh(model_parallel=1,
                                            data_parallel=1))
    assert _engine_tokens(params, ATTN_CFG, "qat", rules) \
        == _engine_tokens(params, ATTN_CFG, "qat", None)


@pytest.mark.parametrize("datapath", ["qat", "sc_int"])
def test_spec_decode_mesh_on_equals_mesh_off(datapath):
    """The speculative fourth of the differential: drafting on
    sc_int_approx and verifying on the sharded target datapath emits
    the same tokens as the mesh-off spec engine AND the mesh-off
    plain engine — the draft scan, the multi-token verify window, and
    the state-snapshot rollback all preserve the layout pins, so GSPMD
    partitioning cannot perturb a single accept/reject decision."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    sharded = _engine_tokens(params, ATTN_CFG, datapath, _rules(),
                             max_new=6, spec_decode=True, draft_len=3)
    local = _engine_tokens(params, ATTN_CFG, datapath, None,
                           max_new=6, spec_decode=True, draft_len=3)
    plain = _engine_tokens(params, ATTN_CFG, datapath, None, max_new=6)
    assert sharded == local == plain, datapath


def test_spec_decode_sampled_mesh_on_equals_mesh_off():
    """Seeded-sampled speculation under the mesh: the shared
    (seed, position) Gumbel streams are replicated-pinned before every
    draw, so the coupled draft/target draws — and hence the accepted
    prefixes — are bit-identical with and without the mesh."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    sharded = _engine_tokens(params, ATTN_CFG, "sc_int", _rules(),
                             max_new=6, sampling=SAMPLED,
                             spec_decode=True, draft_len=3)
    plain = _engine_tokens(params, ATTN_CFG, "sc_int", None,
                           max_new=6, sampling=SAMPLED)
    assert sharded == plain


def test_logprobs_mesh_on_equals_mesh_off():
    """Logprob records (chosen + top-k) are computed from replicated-
    pinned logits, so the mesh changes neither tokens nor scores —
    including through speculative verify steps."""
    params = init_params(jax.random.key(0), ATTN_CFG)
    sps = [SamplingParams(logprobs=2),
           SamplingParams(temperature=0.8, top_p=0.9, seed=11,
                          logprobs=2),
           SamplingParams(logprobs=2)]
    runs = []
    for rules in (_rules(), None):
        eng = ServeEngine(params, ATTN_CFG, max_slots=2, max_len=32,
                          page_size=8, datapath="qat", mesh_rules=rules,
                          spec_decode=True, draft_len=3)
        for p, sp in zip(PROMPTS, sps):
            eng.submit(p, max_new_tokens=5, sampling=sp)
        done = sorted(eng.run_to_completion(), key=lambda r: r.rid)
        runs.append([(r.generated, r.logprobs) for r in done])
    for (g_a, lp_a), (g_b, lp_b) in zip(*runs):
        assert g_a == g_b
        assert len(lp_a) == len(lp_b) == len(g_a)
        for a, b in zip(lp_a, lp_b):
            assert a["logprob"] == pytest.approx(b["logprob"], abs=1e-6)
            assert [t for t, _ in a["top"]] == [t for t, _ in b["top"]]

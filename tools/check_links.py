"""Markdown link checker for the docs CI job.

Walks every ``*.md`` in the repo (skipping dot-directories), extracts
inline links/images ``[text](target)`` and reference definitions
``[id]: target``, and verifies that every RELATIVE target resolves to an
existing file or directory.  External schemes (http/https/mailto) and
pure in-page anchors are skipped — this job gates the repo's own wiring
(README architecture map, test/bench pointers), not the internet.

Also flags ABSOLUTE filesystem paths (``/root/...``, ``/home/...``,
``/tmp/...``) anywhere in the prose *or* code spans — docs must describe
the repo by relative path so they survive a checkout anywhere.
Machine-generated logs (ISSUE.md, CHANGES.md) are exempt.

    python tools/check_links.py            # check the whole repo
    python tools/check_links.py README.md  # or explicit files
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# inline [text](target) — target ends at the first unescaped ')' or space
_INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# reference definitions: [id]: target
_REFDEF = re.compile(r"^\[[^\]]+\]:\s+(\S+)", re.M)
_SKIP = ("http://", "https://", "mailto:", "ftp://")


def _strip_code(text: str) -> str:
    """Drop fenced and inline code spans — links there are examples."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.sub(r"`[^`]*`", "", text)


def targets(md: Path) -> list[str]:
    text = _strip_code(md.read_text(encoding="utf-8"))
    return _INLINE.findall(text) + _REFDEF.findall(text)


# absolute machine paths that leak a particular checkout/container into
# the docs; scanned on RAW text (stale paths usually hide in backticks)
_ABS_PATH = re.compile(r"(?<![\w.])(/(?:root|home|tmp|Users|mnt|opt)/"
                       r"[\w./-]+)")
# machine-generated per-PR logs, allowed to reference their environment
_ABS_EXEMPT = {"ISSUE.md", "CHANGES.md"}


def abs_paths(md: Path) -> list[tuple[int, str]]:
    if md.name in _ABS_EXEMPT:
        return []
    hits = []
    for i, line in enumerate(md.read_text(encoding="utf-8").splitlines(), 1):
        hits += [(i, m) for m in _ABS_PATH.findall(line)]
    return hits


def check(files: list[Path]) -> list[str]:
    broken = []
    for md in files:
        for tgt in targets(md):
            if tgt.startswith(_SKIP) or tgt.startswith("#"):
                continue
            path = tgt.split("#", 1)[0]
            if not path:
                continue
            resolved = (ROOT / path.lstrip("/")) if path.startswith("/") \
                else (md.parent / path)
            if not resolved.exists():
                broken.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {tgt}")
        for line_no, hit in abs_paths(md):
            broken.append(f"{md.relative_to(ROOT)}:{line_no}: absolute "
                          f"filesystem path in docs -> {hit}")
    return broken


def main() -> int:
    if len(sys.argv) > 1:
        files = [Path(a).resolve() for a in sys.argv[1:]]
    else:
        files = [p for p in sorted(ROOT.rglob("*.md"))
                 if not any(part.startswith(".")
                            for part in p.relative_to(ROOT).parts)]
    broken = check(files)
    for b in broken:
        print(b)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if broken else 'ok'} ({len(broken)} broken)")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python3
"""Static hot-path contract gate: lower every serving configuration and
verify the jaxpr/HLO invariants in ``repro.analysis.contracts``, plus the
AST lint in ``repro.analysis.lint``.

For each (arch, datapath, kv_format) cell the engine's jitted steps are
LOWERED (never executed) and audited for donation, dtype-purity,
host-boundary and sharding coverage; live retrace cells then run a tiny
prompt ladder twice and require zero cache growth on the repeat.  Results
land in ``ANALYSIS.json``; ``--gate`` exits non-zero on any violation so
CI can block on it.

Beyond the engine cells, the report carries a ``kernel_audit`` section:
the static Pallas-kernel auditor (``repro.analysis.kernel_audit``) runs
its bounds / vmem / revisit / grid passes over every kernel registered
in ``kernels/dispatch.KERNEL_REGISTRY`` x its kv_formats x the autotune
sweep shapes — again without executing anything.  ``--vmem-warn``
demotes vmem-budget violations to notes (the latest-jax CI leg uses it:
block layouts may legitimately differ there, bounds/revisit may not).

ANALYSIS.json is stamped with ``"schema": ANALYSIS_SCHEMA``; the gate
refuses to clobber or trust an artifact whose stamp it does not know,
so a stale checkout can never quietly overwrite (or green-light) a
newer report format.

Usage:
    python tools/analyze.py                 # full matrix, write ANALYSIS.json
    python tools/analyze.py --gate          # same + non-zero exit on violation
    python tools/analyze.py --smoke --gate  # 2-cell subset for quick checks
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT / "src") not in sys.path:
    sys.path.insert(0, str(ROOT / "src"))

# Version of the ANALYSIS.json layout this tool reads and writes.
# 2: added top-level "schema", "kernel_audit" (kernel x format x shape
#    cells from repro.analysis.kernel_audit) and the hygiene lint rule.
# 1: implicit — the PR-8 contract-matrix layout, no stamp.
ANALYSIS_SCHEMA = 2
KNOWN_SCHEMAS = (1, 2)


def check_artifact_schema(path: Path) -> int | None:
    """Schema stamp of an existing ANALYSIS.json (1 if pre-stamp, None
    if absent/unreadable).  Raises SystemExit on an unknown stamp — an
    artifact from a newer tool must not be silently clobbered or gated."""
    try:
        prev = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return None
    schema = prev.get("schema", 1) if isinstance(prev, dict) else None
    if schema not in KNOWN_SCHEMAS:
        raise SystemExit(
            f"{path}: unknown ANALYSIS.json schema {schema!r} (this tool "
            f"knows {list(KNOWN_SCHEMAS)}) — refusing to overwrite or "
            "gate on it; update tools/analyze.py or delete the artifact")
    return schema

# Tiny-but-structurally-faithful scale: same shapes the differential test
# suite uses, so every lowering here matches a lowering the tests execute.
SCALE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=64, vocab_pad_multiple=32, dtype="float32",
             attn_q_chunk=8)

# (datapath, kv_format) cells EngineConfig.validate accepts: sc coding
# requires an SC datapath; int8/fp coding pair with any datapath.
CELLS = (("qat", "fp"), ("qat", "int8"), ("sc_int", "fp"),
         ("sc_int", "sc"), ("sc_int_approx", "int8"))
SMOKE_CELLS = (("qat", "fp"), ("sc_int", "sc"))
RECURRENT_CELLS = (("qat", "fp"), ("sc_int", "sc"), ("sc_int_approx", "int8"))

PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]


def _arch_cfgs():
    from repro.configs import LayerSpec, get_arch
    return {
        "granite": get_arch("granite-3-2b").scaled(n_layers=2, **SCALE),
        "mamba": get_arch("jamba-1.5-large-398b").scaled(
            period=(LayerSpec("mamba", "dense"),), n_layers=2, **SCALE,
            mamba_d_state=8),
        "rwkv6": get_arch("rwkv6-7b").scaled(
            n_layers=2, **{**SCALE, "n_kv_heads": 4}),
        "jamba": get_arch("jamba-1.5-large-398b").scaled(
            n_layers=8, **SCALE, mamba_d_state=8, n_experts=4,
            n_experts_per_tok=2, moe_capacity_factor=2.0),
    }


def _cell_results(params, cfg, arch, datapath, kv_format, *, mesh_rules=None,
                  label_suffix="", check_collectives=None):
    from repro.analysis.contracts import run_engine_contracts
    from repro.serving import ServeEngine
    label = f"{arch}/{datapath}/{kv_format}{label_suffix}"
    eng = ServeEngine(params, cfg, max_slots=4, max_len=64,
                      datapath=datapath, kv_format=kv_format,
                      mesh_rules=mesh_rules)
    if check_collectives is None:
        check_collectives = mesh_rules is not None
    return label, run_engine_contracts(eng, label,
                                       check_collectives=check_collectives)


def _retrace_results(params, cfg, arch, datapath, kv_format):
    from repro.analysis.contracts import audit_engine_retrace
    from repro.serving import ServeEngine
    label = f"{arch}/{datapath}/{kv_format}/live"
    eng = ServeEngine(params, cfg, max_slots=4, max_len=64,
                      datapath=datapath, kv_format=kv_format)
    return label, [audit_engine_retrace(eng, PROMPTS, label)]


def run_matrix(smoke: bool = False, skip_lint: bool = False,
               vmem_warn: bool = False) -> dict:
    import jax
    from repro.analysis.contracts import results_to_json
    from repro.analysis.kernel_audit import audit_registry
    from repro.analysis.lint import hygiene_repo, lint_repo
    from repro.launch.mesh import make_serving_mesh, serving_rules
    from repro.models import init_params

    t0 = time.time()
    cfgs = _arch_cfgs()
    archs = ("granite",) if smoke else tuple(cfgs)
    report = {"schema": ANALYSIS_SCHEMA,
              "jax": jax.__version__,
              "backend": jax.default_backend(),
              "device_count": jax.device_count(),
              "smoke": smoke, "cells": {}, "lint": [],
              "kernel_audit": {}, "ok": True}

    for arch in archs:
        cfg = cfgs[arch]
        params = init_params(jax.random.PRNGKey(0), cfg)
        if smoke:
            cells = SMOKE_CELLS
        elif arch == "granite":
            cells = CELLS
        else:
            cells = RECURRENT_CELLS
        for datapath, kv_format in cells:
            label, results = _cell_results(params, cfg, arch, datapath,
                                           kv_format)
            report["cells"][label] = results_to_json(results)
            print(f"  {label}: "
                  f"{'ok' if report['cells'][label]['ok'] else 'FAIL'}")

        # one mesh-sharded cell per arch: n_kv_heads=2 pools genuinely
        # shard at model_parallel=2 (fit_spec degrades non-dividing
        # axes).  The collective wire-bytes budget runs on sc_int — the
        # datapath with a sharded perf story.  sc_int_approx under a
        # mesh is token-correct (test_sharded_serving.py) but re-gathers
        # its operands every step: the interpret-mode pallas BSN call is
        # not GSPMD-partitionable (analysis/README.md, open item), so
        # its mesh cell checks leaf-sharding coverage only.
        if jax.device_count() >= 4 and not smoke:
            rules = serving_rules(make_serving_mesh(model_parallel=2,
                                                    data_parallel=2))
            # rwkv6 is coverage-only too: the audit's first run caught
            # its wkv state pool being all-gathered every decode step
            # (~2.7x budget) — real finding, fix tracked as an open item
            # in analysis/README.md (test_sharded_serving.py does not
            # cover rwkv6 either)
            mesh_cells = [(("sc_int", "sc"), arch != "rwkv6")]
            if arch == "granite":
                mesh_cells.append((("sc_int_approx", "int8"), False))
            for (dp, kf), coll in mesh_cells:
                label, results = _cell_results(
                    params, cfg, arch, dp, kf, mesh_rules=rules,
                    label_suffix="/mesh2x2", check_collectives=coll)
                report["cells"][label] = results_to_json(results)
                print(f"  {label}: "
                      f"{'ok' if report['cells'][label]['ok'] else 'FAIL'}")

        # live retrace cell (prompt ladder twice, zero growth on repeat)
        dp, kf = ("qat", "fp") if arch == "granite" else cells[-1]
        label, results = _retrace_results(params, cfg, arch, dp, kf)
        report["cells"][label] = results_to_json(results)
        print(f"  {label}: "
              f"{'ok' if report['cells'][label]['ok'] else 'FAIL'}")

    if not skip_lint:
        lint = lint_repo() + hygiene_repo()
        report["lint"] = [v.to_dict() for v in lint]
        print(f"  lint: {len(lint)} violation(s)")

    # static kernel audit: every registered kernel x kv_format x sweep
    # shape, never executed.  --vmem-warn demotes vmem failures to notes
    # (bounds/revisit/grid stay fatal).
    ka = audit_registry()
    if vmem_warn:
        for cell in ka["kernels"].values():
            for p in cell["passes"]:
                if p["pass"] == "vmem" and not p["ok"]:
                    p["notes"] += [f"vmem-warn: {v['message']}"
                                   for v in p["violations"]]
                    p["violations"], p["ok"] = [], True
            cell["violation_count"] = sum(len(p["violations"])
                                          for p in cell["passes"])
            cell["ok"] = not cell["violation_count"]
        ka["ok"] = all(c["ok"] for c in ka["kernels"].values())
        ka["vmem_warn"] = True
    report["kernel_audit"] = ka
    nbad = sum(not c["ok"] for c in ka["kernels"].values())
    print(f"  kernel_audit: {len(ka['kernels'])} kernel cells, "
          f"{nbad} failing")

    report["ok"] = (all(c["ok"] for c in report["cells"].values())
                    and not report["lint"] and ka["ok"])
    report["elapsed_s"] = round(time.time() - t0, 1)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero if any pass fails")
    ap.add_argument("--smoke", action="store_true",
                    help="2-cell granite subset (fast CI smoke)")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--vmem-warn", action="store_true",
                    help="kernel-audit vmem violations warn instead of "
                         "failing (latest-jax CI leg)")
    ap.add_argument("--out", default=str(ROOT / "ANALYSIS.json"),
                    help="report path (default: repo-root ANALYSIS.json)")
    args = ap.parse_args(argv)

    check_artifact_schema(Path(args.out))     # fail loudly on unknown stamp
    report = run_matrix(smoke=args.smoke, skip_lint=args.skip_lint,
                        vmem_warn=args.vmem_warn)
    Path(args.out).write_text(json.dumps(report, indent=2) + "\n")

    nvio = sum(c["violation_count"] for c in report["cells"].values()) \
        + sum(c["violation_count"]
              for c in report["kernel_audit"]["kernels"].values()) \
        + len(report["lint"])
    print(f"{len(report['cells'])} cells, {nvio} violation(s) "
          f"-> {args.out} ({report['elapsed_s']}s)")
    if not report["ok"]:
        for label, cell in report["cells"].items():
            for p in cell["passes"]:
                for v in p["violations"]:
                    print(f"FAIL {label} [{p['pass']}] {v['message']}")
        for label, cell in report["kernel_audit"]["kernels"].items():
            for p in cell["passes"]:
                for v in p["violations"]:
                    print(f"FAIL kernel {label} [{p['pass']}] "
                          f"{v['message']}")
        for v in report["lint"]:
            print(f"FAIL lint [{v['rule']}] {v['file']}:{v['line']} "
                  f"{v['message']}")
    return 1 if (args.gate and not report["ok"]) else 0


if __name__ == "__main__":
    sys.exit(main())

"""Approximate-BSN design-space exploration (paper Fig 10b / §IV).

Sweeps the parameterized BSN space (clip window x sampling stride x
temporal fold) for a given accumulation width, bit-exactly measures each
config's MSE, prices it with the calibrated gate model, and prints the
ADP-vs-MSE Pareto front — the co-design loop a hardware team would run
per layer.

    PYTHONPATH=src python examples/design_space.py --width 4608
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import hwmodel
from repro.core.bsn import (ApproxBSNSpec, StageSpec, SubSampleSpec,
                            approx_bsn_counts, spatial_temporal_counts)

IN_BSL = 2


def measure_mse(spec, cycles, n=2048, seed=0):
    key = jax.random.key(seed)
    width = spec.width * cycles
    vals = jax.random.choice(key, jnp.asarray([-1, 0, 1]), (n, width),
                             p=jnp.asarray([0.16, 0.68, 0.16]))
    counts = vals + 1
    exact = jnp.sum(vals, -1)
    if cycles == 1:
        out = approx_bsn_counts(counts, spec)
        approx = spec.scale * (out - spec.out_bsl // 2)
    else:
        out = spatial_temporal_counts(counts, spec, cycles)
        approx = spec.scale * (out - cycles * spec.out_bsl // 2)
    err = (approx - exact).astype(jnp.float32) / width
    return float(jnp.mean(err * err))


def candidates(width):
    """(spec, cycles) grid over clip-window sigmas, strides, folds."""
    out = []
    for fold in (1, 4, 9):
        w = width // fold
        if w * fold != width or w % 64:
            continue
        m = w // 64
        sigma = (w * 0.32) ** 0.5
        for stride in (2, 4, 8):
            for nsig in (2.0, 3.0, 4.0):
                sorted2 = m * 32
                win = int(min(nsig * sigma, sorted2 // 2))
                win = max(stride, win // stride * stride)
                clip = (sorted2 - 2 * win) // 2
                if clip < 0:
                    continue
                try:
                    spec = ApproxBSNSpec(
                        width=w, in_bsl=IN_BSL,
                        stages=(StageSpec(64, SubSampleSpec(48, 1)),
                                StageSpec(m, SubSampleSpec(clip, stride))))
                except ValueError:
                    continue
                out.append((spec, fold, stride, nsig))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=4608)
    args = ap.parse_args()

    base = hwmodel.bsn_cost(args.width * IN_BSL)
    print(f"[dse] width {args.width}: baseline BSN adp={base.adp:.3e} "
          f"(area {base.area_um2:.3e} um2)")

    results = []
    for spec, fold, stride, nsig in candidates(args.width):
        if fold == 1:
            cost = hwmodel.approx_bsn_cost(spec)
            adp = cost.adp
        else:
            cost = hwmodel.spatial_temporal_cost(spec, fold)
            adp = cost.area_um2 * fold * cost.delay_ns
        mse = measure_mse(spec, fold)
        results.append((adp, mse, fold, stride, nsig, spec))

    # Pareto front on (adp, mse)
    results.sort()
    front, best_mse = [], float("inf")
    for r in results:
        if r[1] < best_mse:
            front.append(r)
            best_mse = r[1]

    print(f"[dse] {len(results)} configs, Pareto front:")
    print("   adp_red   mse        fold stride clip_sigma  out_bsl")
    for adp, mse, fold, stride, nsig, spec in front:
        print(f"   {base.adp / adp:6.1f}x  {mse:.2e}  {fold:4d} {stride:5d} "
              f"{nsig:9.1f}  {spec.out_bsl:6d}")
    print("[dse] pick per accuracy budget; bench_approx_bsn.py locks the "
          "paper's Table V / Fig 13 operating points")


if __name__ == "__main__":
    main()

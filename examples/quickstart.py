"""Quickstart: the SC datapath end-to-end at the bit level.

Walks one neuron through the paper's pipeline — thermometer coding
(Table II), ternary multipliers (Fig 3a), BSN accumulation + SI activation
(Fig 3b), BN fusion (Eq 1) — and shows the three equivalent views:
bit-exact circuit == integer datapath == quantized float math.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bsn, coding, multiplier, si


def bits_str(b):
    return "".join(str(int(x)) for x in np.asarray(b))


def main():
    print("=== 1. Thermometer coding (Table II) ===")
    for bsl in (2, 4, 8):
        half = bsl // 2
        codes = [bits_str(coding.encode_thermometer(jnp.asarray(v), bsl))
                 for v in range(-half, half + 1)]
        print(f"  BSL {bsl}: {dict(zip(range(-half, half + 1), codes))}")

    print("\n=== 2. Ternary multiplier (Fig 3a), all 9 cases ===")
    for a in (-1, 0, 1):
        row = []
        for w in (-1, 0, 1):
            p = multiplier.ternary_mul_bits(
                coding.encode_thermometer(jnp.asarray(a), 2),
                coding.encode_thermometer(jnp.asarray(w), 2))
            row.append(f"{a}x{w}={bits_str(p)}({int(coding.decode_thermometer(p))})")
        print("  " + "  ".join(row))

    print("\n=== 3. One neuron: multiply -> BSN sort -> SI ReLU ===")
    alpha = 0.5
    key = jax.random.key(0)
    a_q = jax.random.randint(key, (8,), -4, 5)          # 8 inputs, BSL 8
    w_q = jax.random.randint(jax.random.key(1), (8,), -1, 2)
    print(f"  activations (q): {np.asarray(a_q)}  weights: {np.asarray(w_q)}")
    a_bits = coding.encode_thermometer(a_q, 8)
    prods = multiplier.ternary_scale_bits(w_q, a_bits)   # wiring-level mul
    sorted_bits = bsn.exact_bsn_bits(prods)              # the BSN
    print(f"  sorted bitstream ({sorted_bits.shape[-1]}b): "
          f"{bits_str(sorted_bits)}")
    sum_q = int(coding.counts_from_bits(sorted_bits)) - 8 * 8 // 2
    print(f"  accumulated sum_q = {sum_q}  (integer dot = "
          f"{int(jnp.sum(a_q * w_q))})")
    t = si.si_thresholds(si.relu_fn, 64, 16, alpha_in=alpha, alpha_out=alpha)
    out_bits = si.apply_si_bits(sorted_bits, jnp.asarray(t))
    out_q = int(out_bits.sum()) - 8
    print(f"  SI(ReLU) output code: {bits_str(out_bits)} -> "
          f"value {alpha * out_q:.2f} "
          f"(float ref {max(0.0, alpha * sum_q):.2f})")

    print("\n=== 4. BN-fused ReLU thresholds (Eq 1 / Fig 7) ===")
    t_plain = si.si_thresholds(si.relu_fn, 64, 16, alpha, alpha)
    t_bn = si.si_thresholds(si.bn_relu_fn(gamma=2.0, beta=1.0), 64, 16,
                            alpha, alpha)
    print(f"  plain ReLU thresholds (bits 8-16): {t_plain[8:16]}")
    print(f"  BN-fused  thresholds (bits 8-16): {t_bn[8:16]}  "
          "(beta shifts, gamma re-spaces — zero extra hardware)")

    print("\n=== 5. Same neuron on the Pallas kernel path ===")
    from repro.kernels import ops
    x = a_q[None, :].astype(jnp.int8)
    w = w_q[:, None].astype(jnp.int8)
    out = ops.ternary_matmul(x, w)
    print(f"  ternary_matmul -> {int(out[0, 0])} (== BSN popcount: "
          f"{sum_q})")
    print("\nAll three views agree. See examples/serve_sc.py for a whole "
          "network on the integer datapath.")


if __name__ == "__main__":
    main()

"""End-to-end driver: SC-QAT train a (reduced) zoo LM for a few hundred
steps on the synthetic Markov language, with checkpoint/restart.

This is launch/train.py exercised as a library — the same pjit'd
train_step that the multi-pod dry-run lowers, here on one CPU device with
a granite-family model reduced to ~15M params.

    PYTHONPATH=src python examples/train_qat.py [--steps 300]
"""

import argparse
import os
import tempfile

import jax

from repro.configs import get_arch
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import warmup_cosine
from repro.train import build_train_step, init_train_state, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch("granite-3-2b").scaled(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
        vocab_size=512, vocab_pad_multiple=64, dtype="float32",
        attn_q_chunk=64)
    print(f"[train_qat] {cfg.name} reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"quant={cfg.quant.mode} (W{cfg.quant.weight_bsl}-"
          f"A{cfg.quant.act_bsl}-R{cfg.quant.resid_bsl})")

    params = init_params(jax.random.key(0), cfg)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train_qat] {n / 1e6:.1f}M params")
    state = init_train_state(params, cfg)

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)
    step_fn = jax.jit(build_train_step(
        cfg, lambda s: warmup_cosine(s, 2e-3, 20, args.steps)),
        donate_argnums=0)

    ckpt = os.path.join(tempfile.mkdtemp(), "ckpt")
    os.makedirs(ckpt, exist_ok=True)
    state, hist = run_training(
        step_fn, state, lambda s: ds.batch(s, args.batch), args.steps,
        ckpt_dir=ckpt, ckpt_every=100,
        log_every=max(args.steps // 15, 1))

    floor = ds.entropy_floor()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train_qat] loss {first:.3f} -> {last:.3f} "
          f"(entropy floor of the synthetic language: {floor:.3f})")
    print(f"[train_qat] checkpoints in {ckpt} — rerun resumes from the "
          "latest step (kill -TERM to test preemption safety)")
    assert last < first - 0.5, "SC-QAT LM failed to learn"
    print("[train_qat] OK")


if __name__ == "__main__":
    main()

"""Serve trained models on the integer SC datapath (what the silicon runs).

Part 1 — the paper's TNN MLP, exported:
1. QAT-trains the TNN MLP (784-256-256-10) on the synthetic set;
2. exports every layer to ternary int8 weights + SI threshold tables
   (BN/activation fused into the selective interconnect);
3. serves batched requests through the Pallas ``ternary_matmul`` kernel
   (fused SI epilogue), verifying the integer path against the QAT model.

Part 2 — an LM through ServeEngine v2 (the new serving API):
continuous batching over the paged KV cache, every projection
re-quantized on the fly to the int8 x ternary datapath
(``datapath="sc_int"``), batched decode verified token-for-token
against the per-request sequential oracle — first greedy, then seeded
stochastic sampling (temperature/top-p with a per-request seed), which
must be just as reproducible: the sampler's PRNG streams are keyed by
(seed, position) only.

    PYTHONPATH=src:. python examples/serve_sc.py            # full
    PYTHONPATH=src:. python examples/serve_sc.py --smoke    # CI docs job
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._qat_mlp import DATASET, QatSpec, eval_mlp, train_mlp
from repro.configs import get_arch
from repro.core import si
from repro.core.coding import quantize_levels
from repro.kernels import ops
from repro.models import init_params
from repro.serving import (EngineConfig, SamplingParams, ServeEngine,
                           sequential_generate)

SPEC = QatSpec(weight_bsl=2, act_bsl=8, resid_bsl=None)
ACT_BSL = 8


def export_int_model(params):
    """QAT params -> integer datapath: int8 ternary weights + SI tables."""
    layers = []
    for blk in params["blocks"]:
        w = np.asarray(blk["w"], np.float32)
        aw = float(blk["alpha_w"])
        aa = float(blk["alpha_a"])
        w_int = np.clip(np.round(w / aw), -1, 1).astype(np.int8)
        sum_max = w.shape[0] * ACT_BSL // 2
        # SI realizes ReLU + requantization to the next layer's alpha_a
        t_counts = si.si_thresholds(si.relu_fn, 2 * sum_max, ACT_BSL,
                                    alpha_in=aa * aw, alpha_out=aa)
        t_q = (t_counts.astype(np.int64) - sum_max).astype(np.int32)
        layers.append({"w_int": jnp.asarray(w_int),
                       "thresholds_q": jnp.asarray(
                           np.tile(t_q, (w.shape[1], 1))),
                       "alpha_a": aa})
    return layers


def serve_batch(params, int_layers, x):
    """float input -> frontend (float) -> SC integer core -> logits."""
    h = jax.nn.relu(x @ params["w_in"])                 # frontend stays fp
    alpha_a = int_layers[0]["alpha_a"]
    x_q = quantize_levels(h, alpha_a, ACT_BSL).astype(jnp.int8)
    for layer in int_layers:                            # the SC silicon part
        out_q = ops.ternary_matmul(x_q, layer["w_int"],
                                   layer["thresholds_q"],
                                   min_flops_for_kernel=0,
                                   block_m=128, block_n=128, block_k=128)
        x_q = out_q.astype(jnp.int8)                    # thermometer q codes
    h = x_q.astype(jnp.float32) * int_layers[-1]["alpha_a"]
    return h @ params["w_out"]                          # classifier head fp


def serve_lm_engine(smoke: bool = False):
    """Part 2: continuous-batching LM serving on the integer datapath."""
    cfg = get_arch("granite-3-2b").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=64, vocab_pad_multiple=32, dtype="float32",
        attn_q_chunk=8)
    params = init_params(jax.random.key(0), cfg)
    n_req, max_new = (4, 6) if smoke else (6, 12)
    prompts = [[(3 * i + j) % 64 for j in range(4 + i)]
               for i in range(n_req)]

    # EngineConfig is the typed construction surface: every serving knob
    # in one validated dataclass (kv_format="int8" halves-and-more the
    # KV pool bytes; see serving/README.md "KV pool formats")
    config = EngineConfig(max_slots=4, max_len=64, page_size=16,
                          datapath="sc_int", kv_format="int8").validate()
    eng = ServeEngine.from_config(params, cfg, config)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    t0 = time.time()
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[serve_sc] engine v2: {len(done)} requests through 4 slots, "
          f"{toks} tokens in {dt * 1e3:.0f} ms "
          f"({toks / dt:.0f} tok/s incl. compile), paged KV "
          f"({eng.page_size}-token pages, {config.kv_format} pool), "
          f"int8 x ternary datapath")

    ref = sequential_generate(params, cfg, prompts, max_new_tokens=max_new,
                              max_len=64, datapath="sc_int",
                              kv_format=config.kv_format)
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    assert got == ref, "batched decode diverged from the sequential oracle"
    print("[serve_sc] OK: batched continuous-batching output is "
          "token-identical to per-request sequential decode")

    # seeded stochastic sampling: same engine, nontrivial temperature and
    # top-p, one seed per request — still token-identical to the oracle,
    # because the draw streams are keyed by (seed, position) only
    sps = [SamplingParams(temperature=0.8, top_p=0.9, seed=17 + i)
           for i in range(len(prompts))]
    eng = ServeEngine.from_config(params, cfg, config)
    for p, sp in zip(prompts, sps):
        eng.submit(p, max_new_tokens=max_new, sampling=sp)
    done = eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    ref = sequential_generate(params, cfg, prompts, max_new_tokens=max_new,
                              max_len=64, datapath="sc_int", sampling=sps,
                              kv_format=config.kv_format)
    assert got == ref, "sampled decode diverged from the sequential oracle"
    assert got != sequential_generate(
        params, cfg, prompts, max_new_tokens=max_new, max_len=64,
        datapath="sc_int", kv_format=config.kv_format), \
        "sampling degenerated to greedy"
    print("[serve_sc] OK: seeded sampled decode (temperature=0.8, "
          "top_p=0.9) reproduces the sequential oracle token-for-token")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI variant: fewer QAT steps / eval "
                         "batches, skips the converged-accuracy gate "
                         "(token-identity asserts stay on)")
    args = ap.parse_args()
    steps = 60 if args.smoke else 250
    eval_batches = 1 if args.smoke else 4

    print(f"[serve_sc] QAT-training the TNN (W2-A8), {steps} steps...")
    params = train_mlp(SPEC, steps=steps, seed=0)
    acc_qat = eval_mlp(params, SPEC)
    print(f"[serve_sc] QAT accuracy: {acc_qat * 100:.2f}%")

    int_layers = export_int_model(params)
    n_int8 = sum(int(l["w_int"].size) for l in int_layers)
    print(f"[serve_sc] exported {len(int_layers)} SC layers, "
          f"{n_int8 / 1e3:.0f}k ternary weights, SI tables fused")

    # batched serving through the Pallas kernel (interpret mode on CPU)
    correct = total = 0
    lat = []
    for i in range(eval_batches):
        b = DATASET.batch(30_000 + i, 256)
        t0 = time.time()
        logits = serve_batch(params, int_layers, b["x"])
        logits.block_until_ready()
        lat.append((time.time() - t0) * 1e3)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == b["y"]))
        total += 256
    print(f"[serve_sc] integer-datapath accuracy: {correct / total * 100:.2f}%"
          f" (QAT reference {acc_qat * 100:.2f}%)")
    steady = f"steady {np.mean(lat[1:]):.1f} ms" if len(lat) > 1 \
        else "single batch"
    print(f"[serve_sc] batch-256 latency: first {lat[0]:.1f} ms (compile), "
          f"{steady} on CPU-interpret — "
          "the TPU path compiles the same pallas_call natively")
    drop = acc_qat - correct / total
    # measured drop on the pinned stack is ~2.7pp (SI re-quantization of
    # a 250-step QAT checkpoint); 3.5pp flags real divergence.  The
    # smoke checkpoint is under-trained, so only the full run gates.
    if not args.smoke:
        assert drop < 0.035, f"integer path diverged from QAT by {drop:.3f}"
        print("[serve_sc] OK: silicon-equivalent datapath matches QAT "
              f"within {drop * 100:.2f}pp")

    print("[serve_sc] -- part 2: ServeEngine v2 (paged KV, sc_int) --")
    serve_lm_engine(smoke=args.smoke)


if __name__ == "__main__":
    main()

"""Kernel micro-benchmarks: ternary_matmul (+ fused SI) and bsn_sort.

On this CPU container the Pallas kernels run in interpret mode, so
us_per_call is a correctness-path number, NOT TPU performance; the derived
column reports the MXU-model FLOPs and the roofline-model time on v5e
(int8 path, 394 TFLOP/s).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import V5E
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)

    for (m, k, n) in ((256, 1024, 256), (512, 2048, 512)):
        x = jnp.asarray(rng.integers(-4, 5, (m, k)).astype(np.int8))
        w = jnp.asarray(rng.integers(-1, 2, (k, n)).astype(np.int8))
        us = _time(lambda a, b: ops.ternary_matmul(
            a, b, min_flops_for_kernel=0, block_m=128, block_n=128,
            block_k=256), x, w)
        flops = 2 * m * k * n
        t_v5e = flops / V5E.peak_flops_int8
        ok = bool(jnp.array_equal(
            ops.ternary_matmul(x, w, min_flops_for_kernel=0, block_m=128,
                               block_n=128, block_k=256),
            ref.ternary_matmul_ref(x, w)))
        rows.append((f"ternary_matmul_{m}x{k}x{n}", us,
                     f"exact={ok} flops={flops:.2e} "
                     f"v5e_int8_roofline={t_v5e * 1e6:.2f}us"))

    # fused SI epilogue variant
    m, k, n, out_bsl = 256, 1024, 256, 16
    x = jnp.asarray(rng.integers(-4, 5, (m, k)).astype(np.int8))
    w = jnp.asarray(rng.integers(-1, 2, (k, n)).astype(np.int8))
    t = jnp.sort(jnp.asarray(rng.integers(-k, k, (n, out_bsl)), jnp.int32),
                 axis=-1)
    us = _time(lambda a, b: ops.ternary_matmul(
        a, b, t, min_flops_for_kernel=0, block_m=128, block_n=128,
        block_k=256), x, w)
    ok = bool(jnp.array_equal(
        ops.ternary_matmul(x, w, t, min_flops_for_kernel=0, block_m=128,
                           block_n=128, block_k=256),
        ref.ternary_matmul_ref(x, w, t)))
    rows.append((f"ternary_matmul_si_{m}x{k}x{n}", us,
                 f"exact={ok} si_epilogue=fused(out_bsl={out_bsl})"))

    for (r, length) in ((512, 512), (256, 2048)):
        bits = jnp.asarray(rng.integers(0, 2, (r, length)).astype(np.int8))
        us = _time(lambda b: ops.bsn_sort(b, min_rows_for_kernel=0,
                                          block_r=128), bits)
        ok = bool(jnp.array_equal(
            ops.bsn_sort(bits, min_rows_for_kernel=0, block_r=128),
            ref.bsn_sort_ref(bits)))
        levels = int(np.log2(length)) * (int(np.log2(length)) + 1) // 2
        rows.append((f"bsn_sort_{r}x{length}", us,
                     f"exact={ok} compare_exchange_levels={levels}"))

    # fused approximate BSN (spatial + temporal-reuse) vs count oracle
    from repro.core.bsn import (approx_bsn_counts, default_approx_spec,
                                spatial_temporal_counts)
    from repro.kernels import dispatch
    for (r, width, in_bsl, cycles) in ((256, 128, 2, 1), (256, 512, 2, 1),
                                       (256, 128, 2, 4)):
        spec = default_approx_spec(width, in_bsl)
        c = jnp.asarray(rng.integers(0, in_bsl + 1,
                                     (r, cycles * width)), np.int32)
        us = _time(lambda x: dispatch.approx_bsn(
            x, spec, cycles=cycles, backend="pallas-interpret",
            block_r=128), c)
        oracle = (approx_bsn_counts(c, spec) if cycles == 1
                  else spatial_temporal_counts(c, spec, cycles))
        got = dispatch.approx_bsn(c, spec, cycles=cycles,
                                  backend="pallas-interpret", block_r=128)
        ok = bool(jnp.array_equal(got, oracle))
        rows.append((f"approx_bsn_{r}x{width}L{in_bsl}T{cycles}", us,
                     f"exact={ok} out_bsl={spec.out_bsl} "
                     f"scale={spec.scale}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

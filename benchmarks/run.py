"""Benchmark harness: one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV. Each bench validates a specific
paper claim; the mapping is DESIGN.md §7. Run everything:

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only fsm_vs_bsn,bsn_cost
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    "fsm_vs_bsn",            # Fig 1
    "quant_ablation",        # Table III
    "residual",              # Figs 6/8
    "precision_tradeoff",    # Fig 2 + Table IV
    "ber_fault",             # Fig 5
    "bsn_cost",              # Fig 9 + Table V + Fig 4
    "approx_bsn",            # Figs 10/11/13
    "kernels",               # Pallas datapath kernels
    "serving",               # ServeEngine v2 batched vs per-slot loop
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name = f"benchmarks.bench_{name}"
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us if us else 0.0:.1f},{derived}", flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# BENCH {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

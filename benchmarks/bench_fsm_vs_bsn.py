"""Fig 1: FSM-based stochastic activation vs exact BSN+SI.

The paper's motivating figure: FSM designs on stochastic bitstreams are
inaccurate even at 1024-bit streams; the deterministic BSN+SI is exact at
any BSL.  We sweep input values, measure MSE of (a) Stanh FSM vs tanh,
(b) FSM-ReLU vs ReLU, (c) BSN+SI vs the quantized target (== 0 by design).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fsm_baseline as fsm
from repro.core import si


def run() -> list[tuple]:
    rows = []
    xs = jnp.linspace(-1, 1, 81)
    n_states = 8
    target_tanh = np.tanh(n_states / 2 * np.asarray(xs))
    target_relu = np.maximum(np.asarray(xs), 0.0)

    t0 = time.time()
    for length in (64, 256, 1024):
        key = jax.random.key(length)
        ks = jax.random.split(key, 8)
        est_t, est_r = [], []
        for k in ks:                                   # average 8 trials
            bits = fsm.stochastic_bitstream(xs, length, k)
            est_t.append(fsm.decode_bipolar(fsm.fsm_stanh(bits, n_states)))
            est_r.append(fsm.decode_bipolar(fsm.fsm_relu(bits, n_states)))
        mse_t = float(np.mean((np.mean(est_t, 0) - target_tanh) ** 2))
        mse_r = float(np.mean((np.mean(est_r, 0) - target_relu) ** 2))
        rows.append((f"fsm_stanh_L{length}", None, f"mse={mse_t:.4e}"))
        rows.append((f"fsm_relu_L{length}", None, f"mse={mse_r:.4e}"))

    # exact design: BSN+SI output == quantized target for EVERY input count
    in_max, out_bsl, alpha = 128, 16, 1.0 / 64
    for name, fn, tgt in (("relu", si.relu_fn, target_relu),
                          ("tanh", si.tanh_fn(0.25), None)):
        t = si.si_thresholds(fn, in_max, out_bsl, alpha_in=alpha,
                             alpha_out=alpha * 8)
        c = jnp.arange(in_max + 1)
        out = np.asarray(si.apply_si_counts(c, jnp.asarray(t)))
        v_in = alpha * (np.arange(in_max + 1) - in_max / 2)
        ideal = np.clip(np.round(fn(v_in) / (alpha * 8) + out_bsl / 2),
                        0, out_bsl)
        mse_quant = float(np.mean((out - ideal) ** 2))
        rows.append((f"bsn_si_{name}", None,
                     f"mse_vs_quantized_target={mse_quant:.1e}(exact)"))
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    return [(n, us if u is None else u, d) for n, u, d in rows]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

"""Table III: where does the accuracy go? (weight vs activation quant).

Paper result on CIFAR10: FP 94.27, W2 93.98 (-0.3), A2 84.18 (-10.1),
W2A2 83.51. Mechanism reproduced on SyntheticClassification: ternary
weights are nearly free; 2-bit-BSL activations are the cliff.
"""

from __future__ import annotations

import time

from ._qat_mlp import QatSpec, eval_mlp, train_mlp

CASES = [
    ("baseline_fp", QatSpec(weight_bsl=None, act_bsl=None)),
    ("weight_quantized_w2", QatSpec(weight_bsl=2, act_bsl=None)),
    ("act_quantized_a2", QatSpec(weight_bsl=None, act_bsl=2)),
    ("fully_quantized_w2a2", QatSpec(weight_bsl=2, act_bsl=2)),
]


def run() -> list[tuple]:
    rows = []
    accs = {}
    for name, spec in CASES:
        t0 = time.time()
        params = train_mlp(spec, steps=250)
        acc = eval_mlp(params, spec)
        accs[name] = acc
        rows.append((f"tableIII_{name}", (time.time() - t0) * 1e6,
                     f"top1={acc * 100:.2f}%"))
    # the paper's ordering claims, asserted as derived metrics
    w_drop = accs["baseline_fp"] - accs["weight_quantized_w2"]
    a_drop = accs["baseline_fp"] - accs["act_quantized_a2"]
    rows.append(("tableIII_claim", 0.0,
                 f"w2_drop={w_drop * 100:.2f}pp a2_drop={a_drop * 100:.2f}pp "
                 f"activation_is_the_cliff={a_drop > 3 * max(w_drop, 0.003)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

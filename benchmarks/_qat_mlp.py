"""Shared QAT MLP for the paper-mechanism benchmarks.

The paper's accuracy experiments (Tables III/IV, Figs 2/5/8) ran
ResNet18/CIFAR and a TNN-MLP/MNIST; offline we reproduce the *mechanisms*
on SyntheticClassification (DESIGN.md §8) with the paper's TNN MLP shape
(784-256-256-10) and a residual block so the §III claims are testable:

    W-A-R notation: weight BSL - activation BSL - residual BSL.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.quant import (lsq_fake_quant, ternary_weight_quant,
                              thermometer_act_quant)
from repro.data import SyntheticClassification
from repro.optim import adamw_init, adamw_update

__all__ = ["QatSpec", "init_mlp", "mlp_forward", "train_mlp", "eval_mlp",
           "DATASET"]

DATASET = SyntheticClassification(n_classes=10, dim=784, seed=0)


@dataclass(frozen=True)
class QatSpec:
    weight_bsl: int | None = 2      # None = float weights
    act_bsl: int | None = 2         # None = float activations
    resid_bsl: int | None = None    # None = no residual path at all
    hidden: int = 256
    n_blocks: int = 2


def init_mlp(key: jax.Array, spec: QatSpec) -> dict:
    ks = jax.random.split(key, spec.n_blocks + 2)
    h = spec.hidden
    params = {"w_in": jax.random.normal(ks[0], (784, h)) * (1 / 28.0),
              "blocks": [], "w_out": jax.random.normal(ks[-1], (h, 10)) / jnp.sqrt(h)}
    for i in range(spec.n_blocks):
        params["blocks"].append(
            {"w": jax.random.normal(ks[1 + i], (h, h)) / jnp.sqrt(h),
             "alpha_w": jnp.asarray(0.05),
             "alpha_a": jnp.asarray(0.5),
             "alpha_r": jnp.asarray(0.1)})
    return params


def _q_w(w, alpha, spec: QatSpec):
    if spec.weight_bsl is None:
        return w
    half = spec.weight_bsl // 2
    return lsq_fake_quant(w, alpha, -half, half)


def _q_a(x, alpha, spec: QatSpec):
    if spec.act_bsl is None:
        return x
    return thermometer_act_quant(x, alpha, spec.act_bsl)


def mlp_forward(params: dict, x: jax.Array, spec: QatSpec) -> jax.Array:
    h = jax.nn.relu(x @ params["w_in"])
    for blk in params["blocks"]:
        xa = _q_a(h, blk["alpha_a"], spec)
        wq = _q_w(blk["w"], blk["alpha_w"], spec)
        y = jax.nn.relu(xa @ wq)
        if spec.resid_bsl is not None:
            # high-precision residual fusion (paper §III, Fig 6b)
            r = lsq_fake_quant(h, blk["alpha_r"], -spec.resid_bsl // 2,
                               spec.resid_bsl // 2)
            h = y + r
        else:
            h = y
    return h @ params["w_out"]


def train_mlp(spec: QatSpec, steps: int = 250, batch: int = 256,
              lr: float = 2e-3, seed: int = 0):
    params = init_mlp(jax.random.key(seed), spec)
    opt = adamw_init(params)

    def loss_fn(p, b):
        logits = mlp_forward(p, b["x"], spec)
        oh = jax.nn.one_hot(b["y"], 10)
        return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), -1))

    @jax.jit
    def step(p, o, b, lr_t):
        l, g = jax.value_and_grad(loss_fn)(p, b)
        p, o = adamw_update(g, o, p, lr_t, weight_decay=0.0)
        return p, o, l

    for i in range(steps):
        b = DATASET.batch(i, batch)
        lr_t = lr * min(1.0, (i + 1) / 20)
        params, opt, _ = step(params, opt, b, lr_t)
    return params


def eval_mlp(params: dict, spec: QatSpec, n_batches: int = 10,
             batch: int = 512) -> float:
    correct = total = 0
    for i in range(n_batches):
        b = DATASET.batch(10_000 + i, batch)     # held-out step range
        logits = mlp_forward(params, b["x"], spec)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == b["y"]))
        total += batch
    return correct / total

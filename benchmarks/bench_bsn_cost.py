"""Fig 9 + Table V + Fig 4: BSN hardware cost model.

Fig 9a: superlinear cost vs accumulation width; Fig 9b: ADP overhead of a
max-width BSN on small layers. Table V: baseline vs spatial vs
spatial-temporal approximate BSN for the 3x3x512 conv (4608 products,
9216 bits), with bit-exact MSE. Fig 4: TOPS/W vs voltage (energy model
calibrated at 198.9 TOPS/W @ 0.65 V).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwmodel
from repro.core.bsn import (ApproxBSNSpec, StageSpec, SubSampleSpec,
                            approx_bsn_counts, spatial_temporal_counts)

# Table V workload: 3x3x512 conv = 4608 2-bit products
WIDTH, IN_BSL = 4608, 2

# spatial spec: stage1 sorts groups of 64 (128 bits) and clips the
# near-empty tails (Fig 11: sum of 64 ternary products has sigma~4.5, the
# +-16 window covers 3.5 sigma); stage2 merges 72 compressed codes,
# keeping a +-128 window (3.3 sigma of the 4608-wide sum) at stride 8.
SPATIAL = ApproxBSNSpec(
    width=WIDTH, in_bsl=IN_BSL,
    stages=(StageSpec(64, SubSampleSpec(clip=48, stride=1)),
            StageSpec(72, SubSampleSpec(clip=1024, stride=8))))
# temporal: 512-wide spatial pipeline reused over 9 cycles (Fig 12)
SP_TEMPORAL = ApproxBSNSpec(
    width=512, in_bsl=IN_BSL,
    stages=(StageSpec(64, SubSampleSpec(clip=48, stride=1)),
            StageSpec(8, SubSampleSpec(clip=72, stride=8))))
ST_CYCLES = 9


def measured_mse(spec: ApproxBSNSpec, cycles: int = 1,
                 n: int = 4096, seed: int = 0) -> float:
    """Bit-exact MSE of the approximate BSN vs the exact sum, on the
    near-Gaussian product distribution of Fig 11 (value scale: the sum is
    normalized by width so MSE is comparable to the paper's ~1e-7)."""
    key = jax.random.key(seed)
    width = spec.width * cycles
    # ternary products of quantized gaussians: mostly zeros, few +-1
    probs = jnp.asarray([0.16, 0.68, 0.16])
    vals = jax.random.choice(key, jnp.asarray([-1, 0, 1]), (n, width),
                             p=probs)
    counts = vals + IN_BSL // 2
    exact = jnp.sum(vals, axis=-1)
    if cycles == 1:
        out = approx_bsn_counts(counts, spec)
        approx = spec.scale * (out - spec.out_bsl // 2)
    else:
        out = spatial_temporal_counts(counts, spec, cycles)
        approx = spec.scale * (out - cycles * spec.out_bsl // 2)
    err = (approx - exact).astype(jnp.float32) / width
    return float(jnp.mean(err * err))


def run() -> list[tuple]:
    rows = []
    t0 = time.time()

    # Fig 9a: superlinear growth
    for w in (576, 1152, 2304, 4608, 9216):
        c = hwmodel.bsn_cost(w * IN_BSL)
        rows.append((f"fig9a_bsn_w{w}", 0.0,
                     f"area={c.area_um2:.4g}um2 delay={c.delay_ns:.3f}ns "
                     f"adp={c.adp:.4g}"))
    # Fig 9b: big BSN on small accumulation
    big = hwmodel.bsn_cost(9216)
    small = hwmodel.bsn_cost(576 * IN_BSL)
    rows.append(("fig9b_overhead_small_on_big", 0.0,
                 f"adp_overhead={big.adp / small.adp:.1f}x"))

    # Table V
    base = hwmodel.bsn_cost(WIDTH * IN_BSL)
    spat = hwmodel.approx_bsn_cost(SPATIAL)
    st = hwmodel.spatial_temporal_cost(SP_TEMPORAL, ST_CYCLES)
    mse_s = measured_mse(SPATIAL)
    mse_st = measured_mse(SP_TEMPORAL, ST_CYCLES)
    rows.append(("tableV_baseline", 0.0,
                 f"area={base.area_um2:.3e} delay={base.delay_ns:.2f} "
                 f"adp={base.adp:.3e} (paper 2.95e5/4.33/1.26e6)"))
    rows.append(("tableV_spatial", 0.0,
                 f"area={spat.area_um2:.3e} delay={spat.delay_ns:.2f} "
                 f"adp={spat.adp:.3e} adp_red={base.adp / spat.adp:.1f}x "
                 f"mse={mse_s:.2e} (paper 2.8x, 3.79e-7)"))
    st_adp_throughput = st.area_um2 * ST_CYCLES * st.delay_ns
    rows.append(("tableV_spatial_temporal", 0.0,
                 f"area={st.area_um2:.3e} delay={st.delay_ns:.2f} "
                 f"adp_iso_throughput={st_adp_throughput:.3e} "
                 f"adp_red={base.adp / st_adp_throughput:.1f}x "
                 f"mse={mse_st:.2e} (paper 4.1x)"))

    # Fig 4: energy model
    for v in (0.55, 0.65, 0.75, 0.9):
        rows.append((f"fig4_tops_per_watt_{v}V", 0.0,
                     f"{hwmodel.tops_per_watt(2, v):.1f} TOPS/W"))
    rows.append(("fig4_peak_calibration", 0.0,
                 f"{hwmodel.tops_per_watt(2, 0.65):.1f} TOPS/W "
                 "(paper: 198.9 @ 0.65V/200MHz)"))
    us = (time.time() - t0) * 1e6 / len(rows)
    return [(n, us, d) for n, _, d in rows]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

"""Fig 5: accuracy loss vs bit-error rate — thermometer SC vs binary.

The paper's silicon claim: at the same BER, the thermometer-coded SC
datapath loses ~70% less accuracy than a positional-binary design (a
flipped thermometer bit is +-1 LSB; a flipped binary MSB is +-2^(B-1)).
We inject faults into the trained TNN's activations at every layer.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fault

from ._qat_mlp import DATASET, QatSpec, init_mlp, train_mlp

SPEC = QatSpec(weight_bsl=2, act_bsl=16, resid_bsl=None)
ACT_BSL = 16
BIN_BITS = 5                       # binary carries the same 17-level range


def _forward_faulty(params, x, ber, key, mode: str):
    """Forward with fault injection on every quantized activation."""
    from repro.core.quant import lsq_fake_quant, thermometer_act_quant
    h = jax.nn.relu(x @ params["w_in"])
    for li, blk in enumerate(params["blocks"]):
        alpha = blk["alpha_a"]
        xq = jnp.clip(jnp.round(h / alpha), -ACT_BSL // 2, ACT_BSL // 2
                      ).astype(jnp.int32)
        k = jax.random.fold_in(key, li)
        if ber > 0:
            if mode == "thermometer":
                xq = fault.thermometer_under_ber(xq, ACT_BSL, ber, k)
            else:
                xq = fault.binary_under_ber(xq, BIN_BITS, ber, k)
        xa = xq.astype(jnp.float32) * alpha
        wq = lsq_fake_quant(blk["w"], blk["alpha_w"], -1, 1)
        h = jax.nn.relu(xa @ wq)
    return h @ params["w_out"]


def _acc(params, ber, mode, n_batches=6, batch=512):
    correct = total = 0
    for i in range(n_batches):
        b = DATASET.batch(20_000 + i, batch)
        logits = _forward_faulty(params, b["x"], ber,
                                 jax.random.key(100 + i), mode)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == b["y"]))
        total += batch
    return correct / total


def run() -> list[tuple]:
    rows = []
    t0 = time.time()
    params = train_mlp(SPEC, steps=250, seed=4)
    base = _acc(params, 0.0, "thermometer")
    rows.append(("fig5_soft_accuracy", 0.0, f"top1={base * 100:.2f}%"))
    losses = {}
    for ber in (0.001, 0.005, 0.02, 0.05):
        at = _acc(params, ber, "thermometer")
        ab = _acc(params, ber, "binary")
        losses[ber] = (base - at, base - ab)
        rows.append((f"fig5_ber{ber}", 0.0,
                     f"thermo_loss={(base - at) * 100:.2f}pp "
                     f"binary_loss={(base - ab) * 100:.2f}pp"))
    reds = [1 - lt / lb for lt, lb in losses.values() if lb > 0.002]
    rows.append(("fig5_claim", 0.0,
                 f"avg_accuracy_loss_reduction={np.mean(reds) * 100:.0f}% "
                 "(paper: ~70%)"))
    us = (time.time() - t0) * 1e6 / len(rows)
    return [(n, us, d) for n, _, d in rows]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

"""Fig 2 + Table IV: accuracy vs efficiency (ADP), and how the
high-precision residual breaks the trade-off.

Table IV (paper):  W-A-R   area(um^2)  ADP      acc
                   2-2-2   4349.7      225.36   82.58
                   2-4-4   10683.3     687.47   92.35
                   2-2-16  4406.9      228.32   92.01
Claim: 2-2-16 reaches 2-4-4 accuracy at ~2-2-2 cost (3x ADP saving).

ADP here comes from the calibrated gate model for one 256-wide MAC column
(multipliers + BSN + SI + residual adder at the given BSLs); accuracy from
QAT on the synthetic set.
"""

from __future__ import annotations

import time

from repro.core import hwmodel
from repro.core.bsn import ApproxBSNSpec, StageSpec, SubSampleSpec

from ._qat_mlp import QatSpec, eval_mlp, train_mlp

WIDTH = 256                       # accumulation width of the MLP layers


def datapath_adp(act_bsl: int, resid_bsl: int) -> tuple[float, float]:
    """(area, ADP) of one output neuron's datapath at W2-A{act}-R{resid}."""
    n_bits = WIDTH * act_bsl
    adder = hwmodel.bsn_cost(n_bits)
    total = hwmodel.datapath_cost(WIDTH, adder)
    # residual path: a small BSN merging the (resid_bsl)-bit residual code
    resid = hwmodel.bsn_cost(resid_bsl + 16)
    area = total.area_um2 + resid.area_um2
    delay = total.delay_ns + resid.delay_ns
    return area, area * delay


def run() -> list[tuple]:
    rows = []
    # ---- Fig 2: sweep activation BSL at fixed 2-bit weights -------------
    for abs_ in (2, 4, 8, 16):
        area, adp = datapath_adp(abs_, 0)
        t0 = time.time()
        p = train_mlp(QatSpec(2, abs_, None), steps=200, seed=2)
        acc = eval_mlp(p, QatSpec(2, abs_, None))
        rows.append((f"fig2_w2a{abs_}", (time.time() - t0) * 1e6,
                     f"adp={adp:.3e} top1={acc * 100:.2f}%"))
    # ---- Table IV: W-A-R combos ------------------------------------------
    combos = [("2-2-2", 2, 2), ("2-4-4", 4, 4), ("2-2-16", 2, 16)]
    result = {}
    for name, abs_, rbs in combos:
        area, adp = datapath_adp(abs_, rbs)
        t0 = time.time()
        spec = QatSpec(2, abs_, rbs)
        p = train_mlp(spec, steps=250, seed=3)
        acc = eval_mlp(p, spec)
        result[name] = (adp, acc)
        rows.append((f"tableIV_{name}", (time.time() - t0) * 1e6,
                     f"area={area:.4g}um2 adp={adp:.4g} "
                     f"top1={acc * 100:.2f}%"))
    adp_ratio = result["2-4-4"][0] / result["2-2-16"][0]
    acc_gap = (result["2-4-4"][1] - result["2-2-16"][1]) * 100
    rows.append(("tableIV_claim", 0.0,
                 f"adp_saving_vs_244={adp_ratio:.2f}x "
                 f"acc_gap_vs_244={acc_gap:.2f}pp (paper: 3.0x, 0.34pp)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

"""Fig 8 + Fig 6: high-precision residual recovers the activation cliff.

Paper: +8.69pp (CIFAR10) / +8.12pp (CIFAR100) from the 16-bit-BSL
residual on a 2-2 datapath; 16b residual ~= FP residual (Fig 8b).
"""

from __future__ import annotations

import time

from ._qat_mlp import QatSpec, eval_mlp, train_mlp

CASES = [
    ("w2a2_no_residual", QatSpec(2, 2, resid_bsl=None)),
    ("w2a2_r4", QatSpec(2, 2, resid_bsl=4)),
    ("w2a2_r16", QatSpec(2, 2, resid_bsl=16)),
    ("w2a2_r_fp", QatSpec(2, 2, resid_bsl=1 << 20)),   # effectively float
]


def run() -> list[tuple]:
    rows, accs = [], {}
    for name, spec in CASES:
        t0 = time.time()
        params = train_mlp(spec, steps=250, seed=1)
        acc = eval_mlp(params, spec)
        accs[name] = acc
        rows.append((f"fig8_{name}", (time.time() - t0) * 1e6,
                     f"top1={acc * 100:.2f}%"))
    gain = accs["w2a2_r16"] - accs["w2a2_no_residual"]
    vs_fp = accs["w2a2_r_fp"] - accs["w2a2_r16"]
    rows.append(("fig8_claim", 0.0,
                 f"r16_gain={gain * 100:.2f}pp "
                 f"r16_vs_fp_residual={vs_fp * 100:.2f}pp "
                 f"(paper: +8.69pp, ~0pp)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

"""ServeEngine v2 throughput: batched paged decode vs the per-slot loop.

Measures end-to-end tokens/sec of the continuous-batching engine against
the seed execution model (per-request prefill + one-token-at-a-time
batch-1 decode — exactly what ``serving.sequential_generate`` encodes)
across concurrency levels and prompt-length mixes.  Both sides are
jit-warmed before timing; the sequential baseline reuses its compiled
steps across requests, so the speedup is batching, not caching.

CLI:
    PYTHONPATH=src python benchmarks/bench_serving.py            # full
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke    # CI job
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/bench_serving.py --sharded --smoke
The smoke run writes ``BENCH_serving.json`` (tokens/sec per point +
the 8-way speedup, plus seeded-sampled vs greedy decode throughput —
the cost of the in-jit top-k/top-p filter and categorical draw — plus
recurrent prefill tokens/sec: mamba/rwkv6 through the batched chunked
paged path vs the retired exact-length per-request fallback;
``--recurrent`` runs just that slice, the CI matrix smoke — plus the
paged-attention kernel differential: decode tokens/sec with the
attention backend pinned to the Pallas kernel vs the XLA gather
reference, and per-shape autotune winners from repro.kernels.autotune;
``--paged-kernel`` runs just that slice — plus the compressed KV pool
slice: decode tokens/sec and analytic slots-per-GiB per ``kv_format``
(fp / int8 / sc), with batched==sequential token-identity and the
int8 >= 2x-capacity gate asserted inline; ``--kv-format`` runs just
that slice — plus the speculative-decoding slice: draft on
sc_int_approx, verify on qat / sc_int, recording wall tokens/sec,
acceptance rate and tokens-per-round per pair (token identity
asserted before timing) and the coupled-ceiling cells whose >=1.5x
verifier-step reduction is gated; ``--spec-decode`` runs just that
slice).  The artifact is written to
the REPO ROOT so it is committable.  ``--sharded``
additionally measures the mesh-sharded engine against the unsharded one
on the same prompts and writes ``BENCH_serving_sharded.json``.  On
forced host devices the sharded path is expected to be SLOWER (every
collective is a host copy) — the artifact tracks the overhead trend,
it is not gated.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LayerSpec, get_arch
from repro.kernels import autotune
from repro.models import decode_step, init_params, prefill
from repro.serving import SamplingParams, ServeEngine
from repro.serving.engine import _pad_prefill_cache

# bench artifacts land at the REPO ROOT regardless of cwd, so the smoke
# JSONs are stable, committable and comparable across PRs (they used to
# exist only as CI artifacts — the perf trajectory was empty)
ROOT = pathlib.Path(__file__).resolve().parents[1]

MAX_LEN = 64
PAGE = 16

CFG = get_arch("granite-3-2b").scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=64, vocab_pad_multiple=32, dtype="float32", attn_q_chunk=8)

# recurrent mixers: chunked state-carrying paged prefill vs the retired
# exact-length per-request fallback (prefill_mode="exact" debug oracle)
_RSCALE = dict(d_model=64, n_heads=4, d_ff=128, vocab_size=64,
               vocab_pad_multiple=32, dtype="float32")
RECURRENT_CFGS = {
    "mamba": get_arch("jamba-1.5-large-398b").scaled(
        period=(LayerSpec("mamba", "dense"),), n_layers=2,
        n_kv_heads=2, mamba_d_state=8, **_RSCALE),
    "rwkv6": get_arch("rwkv6-7b").scaled(
        n_layers=2, n_kv_heads=4, rwkv_head_dim=16, **_RSCALE),
}

MIXES = {
    "uniform8": lambda n: [[(7 * i + j) % 64 for j in range(8)]
                           for i in range(n)],
    "mixed4to24": lambda n: [[(5 * i + j) % 64
                              for j in range(4 + (i * 5) % 21)]
                             for i in range(n)],
}


def _engine_tps(params, n_req, prompts_fn, max_new, cfg=None,
                rules=None, sampled=False, attn_backend=None,
                datapath="qat", kv_format="fp") -> float:
    eng = ServeEngine(params, cfg if cfg is not None else CFG,
                      max_slots=min(n_req, 8), max_len=MAX_LEN,
                      page_size=PAGE, mesh_rules=rules,
                      attn_backend=attn_backend, datapath=datapath,
                      kv_format=kv_format)
    # seeded stochastic decode (vs the default greedy): same jitted step,
    # plus the in-jit filter + categorical draw per token
    sps = [SamplingParams(temperature=0.8, top_p=0.9, top_k=32, seed=i)
           for i in range(n_req)] if sampled else [None] * n_req

    def wave():
        for p, sp in zip(prompts_fn(n_req), sps):
            eng.submit(p, max_new_tokens=max_new, sampling=sp)
        done = eng.run_to_completion()
        return sum(len(r.generated) for r in done)

    wave()                                    # compile every bucket
    t0 = time.time()
    toks = wave()
    return toks / (time.time() - t0)


def _sequential_tps(params, n_req, prompts_fn, max_new) -> float:
    """The seed per-slot loop, jitted once and warmed (see module doc)."""
    prefill_fn = jax.jit(lambda b: prefill(params, b, CFG))
    decode_fn = jax.jit(lambda c, t: decode_step(params, c, t, CFG))

    def wave():
        total = 0
        for prompt in prompts_fn(n_req):
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            logits, cache = prefill_fn({"tokens": toks})
            cache = _pad_prefill_cache(cache, MAX_LEN)
            gen = [int(jnp.argmax(logits[0, -1, :CFG.vocab_size]))]
            while len(gen) < max_new:
                tok = jnp.asarray([[gen[-1]]], jnp.int32)
                logits, cache = decode_fn(cache, tok)
                gen.append(int(jnp.argmax(logits[0, 0, :CFG.vocab_size])))
            total += len(gen)
        return total

    wave()
    t0 = time.time()
    toks = wave()
    return toks / (time.time() - t0)


def _recurrent_prefill_tps(params, cfg, prefill_mode, n_req) -> float:
    """PREFILL tokens/sec for a recurrent arch (max_new_tokens=1 so the
    wave is prefill-dominated).  The mixed-length prompt set makes the
    exact path pay its real cost: one compiled variant per distinct
    prompt length vs the chunked path's pow2 buckets."""
    eng = ServeEngine(params, cfg, max_slots=8, max_len=MAX_LEN,
                      page_size=PAGE, prefill_mode=prefill_mode)
    prompts = MIXES["mixed4to24"](n_req)

    def wave():
        for p in prompts:
            eng.submit(p, max_new_tokens=1)
        done = eng.run_to_completion()
        return sum(len(r.prompt) for r in done)

    wave()                                    # compile every variant
    t0 = time.time()
    toks = wave()
    return toks / (time.time() - t0)


def run_recurrent(smoke: bool = False):
    """Recurrent prefill: batched chunked-paged vs the old exact-length
    per-request fallback, prompt tokens/sec (recorded, not gated)."""
    n_req = 8 if smoke else 16
    rows, results = [], {}
    for name, cfg in RECURRENT_CFGS.items():
        params = init_params(jax.random.key(0), cfg)
        tps_c = _recurrent_prefill_tps(params, cfg, "chunked", n_req)
        tps_e = _recurrent_prefill_tps(params, cfg, "exact", n_req)
        key = f"recurrent_prefill_{name}"
        results[key] = {"chunked_tps": tps_c, "exact_tps": tps_e,
                        "chunked_vs_exact": tps_c / tps_e}
        rows.append((key, 1e6 / tps_c,
                     f"chunked_tps={tps_c:.1f} exact_tps={tps_e:.1f} "
                     f"chunked_vs_exact={tps_c / tps_e:.2f}x"))
    return rows, results


def run_paged(smoke: bool = False):
    """Paged-attention kernel vs the XLA gather/scatter reference:
    engine decode tokens/sec with the attention backend pinned each way,
    plus the per-shape autotune winners (split-K width for decode,
    q-block rows for chunked prefill).  On this CPU container the
    kernel leg runs the Pallas interpreter, so kernel_vs_xla tracks
    dispatch + interpreter overhead (expected << 1); on a TPU the same
    rows time Mosaic.  The schema is stable either way — that is what
    the root-level artifact is for."""
    params = init_params(jax.random.key(0), CFG)
    n_req, max_new = 8, (8 if smoke else 16)
    rows, results = [], {}
    tps_k = _engine_tps(params, n_req, MIXES["uniform8"], max_new,
                        attn_backend="pallas-interpret")
    tps_r = _engine_tps(params, n_req, MIXES["uniform8"], max_new,
                        attn_backend="reference")
    key = "paged_attn_decode_uniform8_n8"
    results[key] = {"kernel_tps": tps_k, "xla_gather_tps": tps_r,
                    "kernel_vs_xla": tps_k / tps_r,
                    "kernel_backend": "pallas-interpret"}
    rows.append((key, 1e6 / tps_k,
                 f"kernel_tps={tps_k:.1f} xla_gather_tps={tps_r:.1f} "
                 f"kernel_vs_xla={tps_k / tps_r:.2f}x"))
    # autotune sweeps at the serving shapes (and one longer-context
    # decode shape where split-K has room to matter)
    iters = 3 if smoke else 10
    hkv, gq = CFG.n_kv_heads, CFG.n_heads // CFG.n_kv_heads
    dh = CFG.d_model // CFG.n_heads
    tune = {
        "decode_serving": autotune.autotune_paged_decode(
            8, hkv, gq, dh, PAGE, MAX_LEN // PAGE, iters=iters),
        "decode_long": autotune.autotune_paged_decode(
            8, hkv, gq, dh, PAGE, 16, splits=(1, 2, 4, 8), iters=iters),
        "prefill_chunk": autotune.autotune_paged_prefill(
            4, 32, hkv, gq, dh, PAGE, 32, block_qs=(8, 16, 32),
            iters=iters),
    }
    results["paged_attn_autotune"] = tune
    for name, t in tune.items():
        rows.append((f"paged_autotune_{name}",
                     t["us_per_call"][t["winner"]],
                     f"winner={t['winner']}"))
    return rows, results


def run_kv_formats(smoke: bool = False):
    """Compressed KV pools: decode tokens/sec + slots-per-GiB per
    ``kv_format``.  Every format runs datapath="sc_int" so "sc" is a
    legal pairing and the comparison isolates the cache format.  Before
    timing, each format's engine is checked token-identical to its
    same-format B=1 sequential oracle, and the sc round-trip error is
    checked against its analytic bound — a perf number can never ship
    for a wrong-token configuration.  The capacity gate (int8 >= 2x fp
    slots at unchanged page_size) is asserted here as in the tests."""
    from repro.core.kv_quant import (KV_FORMATS, kv_dequant,
                                     kv_error_bound, kv_quant)
    from repro.serving import sequential_generate, slots_per_gib
    params = init_params(jax.random.key(0), CFG)
    n_req, max_new = 8, (8 if smoke else 16)
    hkv, dh = CFG.n_kv_heads, CFG.d_model // CFG.n_heads
    # sc accuracy: the cache round-trip honors |err| <= alpha_r / 2
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (64, hkv, dh)), jnp.float32)
    qd = kv_quant(x, "sc")
    err = jnp.abs(kv_dequant(qd["q"], qd["scale"], qd["resid"],
                             fmt="sc") - x)
    bound = kv_error_bound(qd["scale"], "sc")[..., None]
    assert bool(jnp.all(err <= bound * (1 + 1e-6))), "sc bound violated"
    rows, results = [], {}
    spg = {f: slots_per_gib(MAX_LEN, PAGE, hkv, dh, f,
                            n_layers=CFG.n_layers) for f in KV_FORMATS}
    assert spg["int8"] >= 2.0 * spg["fp"], \
        f"int8 capacity gate: {spg['int8'] / spg['fp']:.2f}x < 2x"
    prompts = MIXES["uniform8"](3)
    for fmt in KV_FORMATS:
        eng = ServeEngine(params, CFG, max_slots=2, max_len=MAX_LEN,
                          page_size=PAGE, datapath="sc_int",
                          kv_format=fmt)
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        got = [r.generated for r in sorted(eng.run_to_completion(),
                                           key=lambda r: r.rid)]
        want = sequential_generate(params, CFG, prompts,
                                   max_new_tokens=4, max_len=MAX_LEN,
                                   datapath="sc_int", kv_format=fmt)
        assert got == want, f"{fmt}: batched != sequential"
        tps = _engine_tps(params, n_req, MIXES["uniform8"], max_new,
                          datapath="sc_int", kv_format=fmt)
        key = f"serving_kv_{fmt}_uniform8_n8"
        results[key] = {"decode_tps": tps,
                        "slots_per_gib": spg[fmt],
                        "slots_vs_fp": spg[fmt] / spg["fp"]}
        rows.append((key, 1e6 / tps,
                     f"decode_tps={tps:.1f} "
                     f"slots_per_gib={spg[fmt]:.0f} "
                     f"slots_vs_fp={spg[fmt] / spg['fp']:.2f}x"))
    return rows, results


def _spec_tps(params, n_req, prompts_fn, max_new, datapath,
              spec: bool, draft_len: int = 4, perfect: bool = False):
    """Wall tokens/sec + spec_stats for one engine configuration.
    ``perfect=True`` points the drafter at the target datapath (the
    coupled ceiling: acceptance is 1.0 by construction)."""
    eng = ServeEngine(params, CFG, max_slots=min(n_req, 8),
                      max_len=MAX_LEN, page_size=PAGE, datapath=datapath,
                      spec_decode=spec, draft_len=draft_len)
    if perfect:
        eng.cfg_draft = eng.cfg

    def wave():
        for p in prompts_fn(n_req):
            eng.submit(p, max_new_tokens=max_new)
        done = eng.run_to_completion()
        return sum(len(r.generated) for r in done)

    wave()
    t0 = time.time()
    toks = wave()
    return toks / (time.time() - t0), dict(eng.spec_stats) if spec else {}


def run_spec_decode(smoke: bool = False):
    """Cross-datapath speculative decoding: draft on sc_int_approx,
    verify on the target datapath in ONE batched multi-token step.

    Two families of cells:

    * ``spec_approx_to_{qat,sc_int}`` — the paper's pairing, recorded
      honestly.  Before timing, spec-on is asserted token-identical to
      spec-off (greedy) — a perf number can never ship for a
      wrong-token configuration.  NOTE the simulation-vs-silicon cost
      inversion: on real SC hardware the approximate-BSN drafter is the
      cheap path (that is the paper's whole premise), but this repo
      SIMULATES the approximate adder with extra integer ops, so here
      the drafter costs MORE wall-clock per step than the target
      (jaxpr op counts: qat 396 / sc_int 418 / sc_int_approx 562 on
      the bench config).  Wall speedup < 1 on this box is therefore
      expected and NOT gated; the hardware-relevant number is the
      verifier-side step reduction below.
    * ``spec_coupled_ceiling_*`` — drafter == target (the acceptance
      ceiling the shared-Gumbel coupling guarantees): acceptance rate
      is exactly 1.0 and the engine takes ``ceil((max_new-1)/(k+1))``
      verify rounds instead of ``max_new-1`` decode ticks.  The
      ``verifier_step_reduction`` cell is gated >= 1.5x — on silicon,
      where drafting is nearly free, this bounds the decode speedup.
    """
    params = init_params(jax.random.key(0), CFG)
    n_req, max_new, k = 8, (8 if smoke else 16), 4
    prompts = MIXES["uniform8"]
    rows, results = [], {}
    for target in ("qat", "sc_int"):
        # token identity first (greedy): spec must change nothing
        outs = []
        for spec in (True, False):
            eng = ServeEngine(params, CFG, max_slots=4, max_len=MAX_LEN,
                              page_size=PAGE, datapath=target,
                              spec_decode=spec, draft_len=k)
            for p in prompts(4):
                eng.submit(p, max_new_tokens=max_new)
            outs.append([r.generated for r in
                         sorted(eng.run_to_completion(),
                                key=lambda r: r.rid)])
        assert outs[0] == outs[1], f"{target}: spec-on != spec-off"

        base_tps, _ = _spec_tps(params, n_req, prompts, max_new, target,
                                spec=False)
        spec_tps, st = _spec_tps(params, n_req, prompts, max_new, target,
                                 spec=True, draft_len=k)
        key = f"spec_approx_to_{target}_uniform8_n8"
        results[key] = {
            "spec_decode_tps": spec_tps, "baseline_tps": base_tps,
            "wall_speedup": spec_tps / base_tps,
            "acceptance_rate": st["acceptance_rate"],
            "tokens_per_round": st["tokens_per_round"],
            "draft_len": k, "drafter": "sc_int_approx",
        }
        rows.append((key, 1e6 / spec_tps,
                     f"spec_tps={spec_tps:.1f} base_tps={base_tps:.1f} "
                     f"wall_speedup={spec_tps / base_tps:.2f}x "
                     f"accept={st['acceptance_rate']:.2f}"))

        # the coupled ceiling: drafter == target, acceptance 1.0
        ctps, cst = _spec_tps(params, n_req, prompts, max_new, target,
                              spec=True, draft_len=k, perfect=True)
        # stats accumulate over the warm + timed wave (2 waves); a plain
        # engine spends max_new-1 decode ticks per wave (prefill emits
        # token 1), the spec engine cst["rounds"]/2 verify rounds
        plain_steps = max_new - 1
        reduction = 2 * plain_steps / cst["rounds"]
        ckey = f"spec_coupled_ceiling_{target}_uniform8_n8"
        results[ckey] = {
            "spec_decode_tps": ctps,
            "acceptance_rate": cst["acceptance_rate"],
            "tokens_per_round": cst["tokens_per_round"],
            "verifier_steps_plain": plain_steps,
            "verifier_rounds_spec": cst["rounds"] / 2,
            "verifier_step_reduction": reduction,
            "draft_len": k, "drafter": target,
        }
        rows.append((ckey, 1e6 / ctps,
                     f"accept={cst['acceptance_rate']:.2f} "
                     f"rounds={cst['rounds'] / 2:.0f} vs {plain_steps} "
                     f"ticks step_reduction={reduction:.2f}x"))
        assert cst["acceptance_rate"] == 1.0, \
            f"{target}: coupled ceiling acceptance {cst['acceptance_rate']}"
        assert reduction >= 1.5, \
            f"{target}: verifier step reduction {reduction:.2f}x < 1.5x"
    return rows, results


def run(smoke: bool = False) -> list[tuple]:
    params = init_params(jax.random.key(0), CFG)
    max_new = 8 if smoke else 16
    slot_counts = (8,) if smoke else (1, 4, 8)
    mixes = ("uniform8",) if smoke else tuple(MIXES)
    rows, results = [], {}
    for mix in mixes:
        for n in slot_counts:
            tps_b = _engine_tps(params, n, MIXES[mix], max_new)
            tps_s = _sequential_tps(params, n, MIXES[mix], max_new)
            # sampled decode (temperature/top-k/top-p inside the jit) vs
            # greedy: tracks what the filter + categorical draw cost per
            # decoded token — recorded, not gated
            tps_smp = _engine_tps(params, n, MIXES[mix], max_new,
                                  sampled=True)
            speedup = tps_b / tps_s
            key = f"serving_{mix}_n{n}"
            results[key] = {"batched_tps": tps_b, "sequential_tps": tps_s,
                            "speedup": speedup, "sampled_tps": tps_smp,
                            "sampled_vs_greedy": tps_smp / tps_b}
            rows.append((key, 1e6 / tps_b,
                         f"batched_tps={tps_b:.1f} seq_tps={tps_s:.1f} "
                         f"speedup={speedup:.2f}x "
                         f"sampled_tps={tps_smp:.1f} "
                         f"sampled_vs_greedy={tps_smp / tps_b:.2f}x"))
    # recurrent prefill trajectory rides in the same artifact
    rrows, rresults = run_recurrent(smoke=smoke)
    rows += rrows
    results.update(rresults)
    # ...and so do the paged-kernel differential + autotune winners
    prows, presults = run_paged(smoke=smoke)
    rows += prows
    results.update(presults)
    # ...and the per-kv_format decode throughput + capacity accounting
    krows, kresults = run_kv_formats(smoke=smoke)
    rows += krows
    results.update(kresults)
    # ...and the speculative-decoding slice (honest cross-datapath
    # pairs + the gated coupled-ceiling step reduction)
    srows, sresults = run_spec_decode(smoke=smoke)
    rows += srows
    results.update(sresults)
    return rows if not smoke else (rows, results)


def run_sharded(smoke: bool = False):
    """Mesh-sharded engine vs the same engine unsharded, same prompts.

    Needs a multi-device jax (CI forces 8 host devices).  The sharded
    engine must produce the same token count — token identity is the
    test suite's job (tests/test_sharded_serving.py); here we track the
    collective overhead on the forced-host mesh.
    """
    ndev = jax.device_count()
    if ndev < 2:
        raise SystemExit(
            "--sharded needs a multi-device jax; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.launch.mesh import make_serving_mesh, serving_rules
    # tp must divide n_heads=4 (GQA grouping) AND equal the KV head
    # count so the pools shard: 4-way when possible, else 2-way
    tp = 4 if ndev >= 4 else 2
    dp = 2 if ndev >= 2 * tp else 1
    rules = serving_rules(make_serving_mesh(model_parallel=tp,
                                            data_parallel=dp))
    cfg = CFG.scaled(n_kv_heads=tp)
    params = init_params(jax.random.key(0), cfg)
    max_new = 8 if smoke else 16
    mixes = ("uniform8",) if smoke else tuple(MIXES)
    rows, results = [], {}
    for mix in mixes:
        tps_sh = _engine_tps(params, 8, MIXES[mix], max_new, cfg=cfg,
                             rules=rules)
        tps_un = _engine_tps(params, 8, MIXES[mix], max_new, cfg=cfg)
        key = f"serving_sharded_{mix}_n8"
        results[key] = {"sharded_tps": tps_sh, "unsharded_tps": tps_un,
                        "ratio": tps_sh / tps_un, "devices": ndev,
                        "mesh": f"{dp}x{tp}"}
        rows.append((key, 1e6 / tps_sh,
                     f"sharded_tps={tps_sh:.1f} unsharded_tps={tps_un:.1f} "
                     f"ratio={tps_sh / tps_un:.2f}x mesh={dp}x{tp}"))
    return rows, results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one fast point; write BENCH_serving.json")
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-sharded engine vs unsharded (needs "
                         "multi-device jax); writes "
                         "BENCH_serving_sharded.json")
    ap.add_argument("--recurrent", action="store_true",
                    help="recurrent prefill only: mamba + rwkv6 through "
                         "the engine, chunked-paged vs the exact "
                         "fallback (the CI matrix smoke)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="paged-attention kernel slice only: kernel vs "
                         "XLA-gather decode tokens/sec + autotune "
                         "sweeps (the CI matrix smoke)")
    ap.add_argument("--kv-format", action="store_true",
                    help="compressed KV pool slice only: per-kv_format "
                         "decode tokens/sec + slots-per-GiB, with the "
                         "batched==sequential and int8>=2x capacity "
                         "asserts (the CI matrix smoke)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding slice only: draft on "
                         "sc_int_approx / verify on qat and sc_int, "
                         "with spec-on==spec-off token identity and "
                         "the coupled-ceiling >=1.5x verifier step "
                         "reduction asserted (the CI matrix smoke)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless batched/sequential >= this at every "
                         "measured point (CI gate; local bar is 3x at 8 "
                         "slots, CI uses margin for runner noise)")
    args = ap.parse_args()
    if sum((args.sharded, args.recurrent, args.paged_kernel,
            args.kv_format, args.spec_decode)) > 1:
        ap.error("--sharded / --recurrent / --paged-kernel / --kv-format "
                 "/ --spec-decode are mutually exclusive")
    if (args.recurrent or args.paged_kernel or args.kv_format
            or args.spec_decode) and (args.out or args.min_speedup):
        ap.error("--recurrent/--paged-kernel/--kv-format/--spec-decode "
                 "ignore --out/--min-speedup; run the full --smoke to "
                 "record/gate")
    if args.out is None:
        name = "BENCH_serving_sharded.json" if args.sharded \
            else "BENCH_serving.json"
        args.out = str(ROOT / name)
    if args.sharded:
        rows, results = run_sharded(smoke=args.smoke)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}")
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")
        return
    if args.recurrent or args.paged_kernel or args.kv_format \
            or args.spec_decode:
        # standalone CI-matrix smokes (exercised on pinned AND latest
        # jax); the full --smoke run is what records these numbers into
        # BENCH_serving.json
        runner = (run_paged if args.paged_kernel else
                  run_kv_formats if args.kv_format else
                  run_spec_decode if args.spec_decode else run_recurrent)
        rows, _ = runner(smoke=args.smoke)
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")
        return
    if args.smoke:
        rows, results = run(smoke=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}")
    else:
        rows = run()
        results = None
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    if args.min_speedup and results:
        # the gate covers batched-vs-sequential decode only; recurrent
        # prefill entries are a recorded trajectory, not a bar
        worst = min(r["speedup"] for r in results.values()
                    if "speedup" in r)
        if worst < args.min_speedup:
            raise SystemExit(f"speedup {worst:.2f}x below the "
                             f"{args.min_speedup}x gate")


if __name__ == "__main__":
    main()

"""ServeEngine v2 throughput: batched paged decode vs the per-slot loop.

Measures end-to-end tokens/sec of the continuous-batching engine against
the seed execution model (per-request prefill + one-token-at-a-time
batch-1 decode — exactly what ``serving.sequential_generate`` encodes)
across concurrency levels and prompt-length mixes.  Both sides are
jit-warmed before timing; the sequential baseline reuses its compiled
steps across requests, so the speedup is batching, not caching.

CLI:
    PYTHONPATH=src python benchmarks/bench_serving.py            # full
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke    # CI job
The smoke run writes ``BENCH_serving.json`` (tokens/sec per point +
the 8-way speedup) for the perf-trajectory artifact.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import decode_step, init_params, prefill
from repro.serving import ServeEngine
from repro.serving.engine import _pad_prefill_cache

MAX_LEN = 64
PAGE = 16

CFG = get_arch("granite-3-2b").scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=64, vocab_pad_multiple=32, dtype="float32", attn_q_chunk=8)

MIXES = {
    "uniform8": lambda n: [[(7 * i + j) % 64 for j in range(8)]
                           for i in range(n)],
    "mixed4to24": lambda n: [[(5 * i + j) % 64
                              for j in range(4 + (i * 5) % 21)]
                             for i in range(n)],
}


def _engine_tps(params, n_req, prompts_fn, max_new) -> float:
    eng = ServeEngine(params, CFG, max_slots=min(n_req, 8),
                      max_len=MAX_LEN, page_size=PAGE)

    def wave():
        for p in prompts_fn(n_req):
            eng.submit(p, max_new_tokens=max_new)
        done = eng.run_to_completion()
        return sum(len(r.generated) for r in done)

    wave()                                    # compile every bucket
    t0 = time.time()
    toks = wave()
    return toks / (time.time() - t0)


def _sequential_tps(params, n_req, prompts_fn, max_new) -> float:
    """The seed per-slot loop, jitted once and warmed (see module doc)."""
    prefill_fn = jax.jit(lambda b: prefill(params, b, CFG))
    decode_fn = jax.jit(lambda c, t: decode_step(params, c, t, CFG))

    def wave():
        total = 0
        for prompt in prompts_fn(n_req):
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            logits, cache = prefill_fn({"tokens": toks})
            cache = _pad_prefill_cache(cache, MAX_LEN)
            gen = [int(jnp.argmax(logits[0, -1, :CFG.vocab_size]))]
            while len(gen) < max_new:
                tok = jnp.asarray([[gen[-1]]], jnp.int32)
                logits, cache = decode_fn(cache, tok)
                gen.append(int(jnp.argmax(logits[0, 0, :CFG.vocab_size])))
            total += len(gen)
        return total

    wave()
    t0 = time.time()
    toks = wave()
    return toks / (time.time() - t0)


def run(smoke: bool = False) -> list[tuple]:
    params = init_params(jax.random.key(0), CFG)
    max_new = 8 if smoke else 16
    slot_counts = (8,) if smoke else (1, 4, 8)
    mixes = ("uniform8",) if smoke else tuple(MIXES)
    rows, results = [], {}
    for mix in mixes:
        for n in slot_counts:
            tps_b = _engine_tps(params, n, MIXES[mix], max_new)
            tps_s = _sequential_tps(params, n, MIXES[mix], max_new)
            speedup = tps_b / tps_s
            key = f"serving_{mix}_n{n}"
            results[key] = {"batched_tps": tps_b, "sequential_tps": tps_s,
                            "speedup": speedup}
            rows.append((key, 1e6 / tps_b,
                         f"batched_tps={tps_b:.1f} seq_tps={tps_s:.1f} "
                         f"speedup={speedup:.2f}x"))
    return rows if not smoke else (rows, results)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one fast point; write BENCH_serving.json")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless batched/sequential >= this at every "
                         "measured point (CI gate; local bar is 3x at 8 "
                         "slots, CI uses margin for runner noise)")
    args = ap.parse_args()
    if args.smoke:
        rows, results = run(smoke=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}")
    else:
        rows = run()
        results = None
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    if args.min_speedup and results:
        worst = min(r["speedup"] for r in results.values())
        if worst < args.min_speedup:
            raise SystemExit(f"speedup {worst:.2f}x below the "
                             f"{args.min_speedup}x gate")


if __name__ == "__main__":
    main()

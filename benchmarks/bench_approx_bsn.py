"""Fig 10 + Fig 13: parameterized BSN design space / per-layer flexibility.

Fig 10a: reducing the BSN *output* BSL barely hurts SI accuracy (the
SI input-output precision gap).  Fig 10b + Fig 13: a design-space sweep
over (clip, stride, temporal fold) per ResNet18 conv size; the
spatial-temporal BSN right-sizes each layer — paper reports 8.2x..23.3x
ADP reduction vs the max-width baseline BSN with negligible MSE.

``kernel_sweep`` additionally times the execution paths of the adder
itself across BSL/width/stage points: exact bit-level sort kernel
(bsn_sort over the concatenated thermometer codes) vs the fused
approximate-BSN kernel vs the jitted count reference.  On this CPU
container the Pallas numbers are interpret-mode (correctness-path)
timings, not TPU performance — the point is the relative shape: the
approximate kernel touches ``width`` counts instead of sorting
``width * BSL`` bits.

``--smoke`` also runs the ``block_r`` autotune sweep per (rows, width)
shape (repro.kernels.autotune) and writes everything to
``BENCH_approx_bsn.json`` at the repo root.
"""

from __future__ import annotations

import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwmodel, si
from repro.core.bsn import (ApproxBSNSpec, StageSpec, SubSampleSpec,
                            default_approx_spec)
from repro.kernels import autotune, dispatch, ops

from .bench_bsn_cost import measured_mse

# artifact lands at the repo root regardless of cwd (committable,
# comparable across PRs) — same policy as bench_serving.py
ROOT = pathlib.Path(__file__).resolve().parents[1]

# ResNet18 conv accumulation widths (3x3 kernels x in-channels)
RESNET_LAYERS = {"3x3x64": 576, "3x3x128": 1152,
                 "3x3x256": 2304, "3x3x512": 4608}
IN_BSL = 2
MAX_WIDTH = 4608


def _spec_for(width: int, sigma: float, stride: int = 8) -> ApproxBSNSpec:
    """Two-stage spatial spec with a ~4-sigma clip window."""
    g1 = 64
    m = width // g1
    s1 = StageSpec(g1, SubSampleSpec(clip=48, stride=1))   # 128 -> 32 bits
    sorted2 = m * 32
    window = int(min(4 * sigma, sorted2 // 2))
    window = max(stride * 2, window // (2 * stride) * (2 * stride))
    clip = (sorted2 - 2 * window) // 2
    return ApproxBSNSpec(width=width, in_bsl=IN_BSL,
                         stages=(s1, StageSpec(m, SubSampleSpec(clip, stride))))


def run() -> list[tuple]:
    rows = []
    t0 = time.time()

    # ---- Fig 10a: output-BSL reduction at the SI --------------------------
    # ReLU output is one-sided: use zero_point=0 so the full out_bsl range
    # covers [0, max]; tanh stays symmetric.
    in_max = 512
    xs = np.arange(in_max + 1)
    import jax.numpy as jnp
    for out_bsl in (64, 32, 16, 8):
        for name, fn, zp in (("relu", si.relu_fn, 0.0),
                             ("tanh", si.tanh_fn(8.0), None)):
            v_in = 0.1 * (xs - in_max / 2)
            ideal = fn(v_in)
            span = float(ideal.max() - ideal.min())
            alpha_out = span / out_bsl
            t = si.si_thresholds(fn, in_max, out_bsl, alpha_in=0.1,
                                 alpha_out=alpha_out, zero_point=zp)
            out = np.asarray(si.apply_si_counts(jnp.asarray(xs),
                                                jnp.asarray(t)))
            zp_eff = out_bsl / 2 if zp is None else zp
            approx = alpha_out * (out - zp_eff)
            mse = float(np.mean((approx - ideal) ** 2))
            rows.append((f"fig10a_{name}_outbsl{out_bsl}", 0.0,
                         f"mse={mse:.2e} rel={mse / np.mean(ideal**2):.1e}"))

    # ---- Fig 13: per-layer right-sizing ------------------------------------
    baseline = hwmodel.bsn_cost(MAX_WIDTH * IN_BSL)   # provisioned for max
    for name, width in RESNET_LAYERS.items():
        sigma = (width * 0.32) ** 0.5
        # spatial-temporal: fold onto a 512-wide pipeline when wider
        if width > 512:
            cycles = width // 512
            spec = _spec_for(512, (512 * 0.32) ** 0.5)
            cost = hwmodel.spatial_temporal_cost(spec, cycles)
            adp = cost.area_um2 * cycles * cost.delay_ns
            mse = measured_mse(spec, cycles)
        else:
            cycles = 1
            spec = _spec_for(width, sigma)
            cost = hwmodel.approx_bsn_cost(spec)
            adp = cost.adp
            mse = measured_mse(spec)
        red = baseline.adp / adp
        rows.append((f"fig13_{name}", 0.0,
                     f"cycles={cycles} adp={adp:.3e} "
                     f"adp_red_vs_max_bsn={red:.1f}x mse={mse:.2e}"))

    avg_red = np.mean([float(r[2].split("adp_red_vs_max_bsn=")[1].split("x")[0])
                       for r in rows if r[0].startswith("fig13")])
    rows.append(("fig13_summary", 0.0,
                 f"avg_adp_reduction={avg_red:.1f}x "
                 "(paper: 8.2x..23.3x, avg 8.5x)"))
    us = (time.time() - t0) * 1e6 / len(rows)
    return [(n, us, d) for n, _, d in rows] + kernel_sweep()


# ---------------------------------------------------------------------------
# execution-path sweep: exact-sort kernel vs fused approx kernel vs reference
# ---------------------------------------------------------------------------

def _time_us(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


# (width, in_bsl, cycles): BSL sweep at fixed width, width sweep at fixed
# BSL, and one temporal fold — at least 3 spec points per the harness.
KERNEL_SWEEP_POINTS = ((128, 2, 1), (128, 4, 1), (512, 2, 1), (128, 2, 4))


def kernel_sweep(rows_batch: int = 256) -> list[tuple]:
    rng = np.random.default_rng(0)
    out = []
    for width, in_bsl, cycles in KERNEL_SWEEP_POINTS:
        spec = default_approx_spec(width, in_bsl)
        total = cycles * width
        counts = jnp.asarray(
            rng.integers(0, in_bsl + 1, (rows_batch, total)), jnp.int32)

        us_ref = _time_us(jax.jit(
            lambda c, s=spec, t=cycles: dispatch.approx_bsn(
                c, s, cycles=t, backend="reference")), counts)
        us_kernel = _time_us(
            lambda c, s=spec, t=cycles: dispatch.approx_bsn(
                c, s, cycles=t, backend="pallas-interpret", block_r=128),
            counts)

        # the exact adder sorts all width*BSL bits of the concatenation
        levels = np.asarray(counts) - in_bsl // 2
        bits = (levels[..., None] + in_bsl // 2
                > np.arange(in_bsl)).astype(np.int8)
        flat = jnp.asarray(bits.reshape(rows_batch, total * in_bsl))
        us_exact = _time_us(
            lambda b: ops.bsn_sort(b, min_rows_for_kernel=0, block_r=128),
            flat)

        ok = bool(jnp.array_equal(
            dispatch.approx_bsn(counts, spec, cycles=cycles,
                                backend="pallas-interpret", block_r=128),
            dispatch.approx_bsn(counts, spec, cycles=cycles,
                                backend="reference")))
        out.append((f"kernel_w{width}L{in_bsl}T{cycles}", us_kernel,
                    f"exact={ok} ref_us={us_ref:.0f} "
                    f"exact_sort_us={us_exact:.0f} "
                    f"fused_vs_exact_sort={us_exact / us_kernel:.1f}x "
                    f"out_bsl={spec.out_bsl} scale={spec.scale}"))
    return out


def autotune_sweep(smoke: bool = False) -> dict:
    """Row-block autotune per (rows, width) shape: the winners land in
    the artifact next to the timing rows, so successive PRs compare
    tile choices, not just end-to-end microseconds."""
    iters = 3 if smoke else 10
    out = {}
    for rows_b, width in ((64, 128), (64, 512), (256, 1152)):
        spec = default_approx_spec(width, IN_BSL)
        out[f"autotune_r{rows_b}_w{width}"] = autotune.autotune_approx_bsn(
            rows_b, spec, block_rs=(64, 128, 256), iters=iters)
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="kernel sweep only (fast); write "
                         "BENCH_approx_bsn.json")
    ap.add_argument("--out", default=str(ROOT / "BENCH_approx_bsn.json"))
    args = ap.parse_args()
    rows = kernel_sweep(rows_batch=64) if args.smoke else run()
    if args.smoke:
        results = {n: {"us_per_call": us, "derived": d}
                   for n, us, d in rows}
        results.update(autotune_sweep(smoke=True))
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}")
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()

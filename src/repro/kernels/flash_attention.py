"""Pallas TPU kernel: fused flash attention (forward / serving path).

Motivated directly by the §Perf attribution: the XLA-lowered flash scan
materializes ~8 logits-sized tensors per (q,kv) tile pair at HBM fusion
boundaries — several TB/step on the train_4k cells.  In this kernel the
whole online-softmax tile pipeline lives in VMEM: HBM traffic is exactly
q + k + v + o (the flash ideal), which is what the roofline's memory term
should charge for attention.

Layout: grid (B*Hq, n_q_blocks); each program brings its q tile and the
(GQA-mapped) kv-head's full K/V into VMEM (32k x 128 bf16 = 8 MiB — fits
v5e VMEM with the default 1024-row q tile) and runs a causal-bounded
fori_loop over kv tiles with m/l/acc carries in registers/VMEM.

Forward only: training still uses the XLA path (a matching backward
kernel is the natural next step — see EXPERIMENTS.md §Perf cell C);
prefill/serving route here via ``cfg.attn_impl = "pallas"`` on TPU.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, seq: int,
            causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...][0].astype(jnp.float32) * scale         # (bq, D)
    d = q.shape[-1]

    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)

    if causal:
        n_kv = (qi + 1) * (bq // bk)      # bq % bk == 0 enforced by caller
    else:
        n_kv = seq // bk

    q_rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kv_full = k_ref[...][0]
    v_full = v_ref[...][0]

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(kv_full, j * bk, bk, 0)
        v = jax.lax.dynamic_slice_in_dim(v_full, j * bk, bk, 0)
        logits = q @ k.astype(jnp.float32).T               # (bq, bk)
        if causal:
            k_cols = j * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 1)
            logits = jnp.where(q_rows >= k_cols, logits, -1e30)
        new_m = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - new_m[:, None])
        corr = jnp.exp(m - new_m)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return new_m, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l[:, None], 1e-30)
                  ).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                              "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, block_q: int = 1024,
                           block_k: int = 1024,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D).

    S must divide by the block sizes (callers pad); Hq % Hkv == 0 (GQA
    head mapping happens in the kv BlockSpec index map).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0 and bq % bk == 0, (S, bq, bk)

    # (B*H, S, D) head-major layouts
    qh = jnp.moveaxis(q, 2, 1).reshape(B * Hq, S, D)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, S, D)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, S, D)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, seq=S, causal=causal,
                               scale=1.0 / math.sqrt(D))

    def kv_index(bh, qi):
        # bh = batch * Hq + q_head  ->  batch * Hkv + q_head // g
        return ((bh // Hq) * Hkv + (bh % Hq) // g, 0, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, S, D), kv_index),
            pl.BlockSpec((1, S, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, Hq, S, D), 1, 2)

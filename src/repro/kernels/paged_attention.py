"""Pallas TPU kernels: flash-decoding paged attention (decode + prefill).

The serving engine keeps KV in a flat pool of fixed-size pages
(serving/paging.py); until now attention over that layout was an
XLA-level gather — ``jnp.take`` materializes every slot's full
``maxp * page`` KV window per decode step (ROADMAP names this the
single biggest raw-speed lever on the decode hot path).  These kernels
instead read the pages *directly through the page table*: the tables
and per-slot lengths ride in as scalar-prefetch operands
(:class:`pltpu.PrefetchScalarGridSpec`), so each grid step's KV
BlockSpec index map resolves ``page_tables[slot, page_idx]`` in SMEM
and Mosaic DMAs exactly one physical page into VMEM — HBM traffic is
q + the live pages + o, never the gathered window.

Decode (`paged_attn_decode_pallas`) is flash-decoding shaped:

* grid ``(S, Hkv, num_splits, pages_per_split)`` — slots and KV heads
  are parallel; the page axis is split-K.  Each program attends the
  slot's G grouped q heads (GQA: all q heads sharing a KV head ride in
  one program, amortizing the page loads) against one page.
* within a split, pages merge by the online-softmax ``(m, l, acc)``
  recurrence accumulated in revisited output blocks; across splits the
  partials merge in one tiny XLA log-sum-exp combine (the flash-
  decoding merge — splits are embarrassingly parallel on the grid).
* per-slot ``lengths`` masking: position ``t`` is live iff
  ``t <= lengths[slot]`` (the just-scattered token sits AT ``lengths``).
  Pages wholly past the length are skipped (``pl.when``), partially
  covered pages mask per position, and padded page-table lanes (which
  point at the reserved trash page) land beyond the length by
  construction — trash never contributes, which the poison tests prove.

Prefill (`paged_attn_prefill_pallas`) covers the chunk-aligned causal
window of ``attn_prefill_paged``: q rows are chunk positions
``[start, start + C)``, KV is every page written so far (pages
``[0, (start + C) / page)``), masked by ``k_pos <= q_pos``.  Blocks of
``block_q`` rows carry ``(m, l, acc)`` in VMEM scratch across the page
loop and normalize on the last page; future pages are skipped per
q-block (the causal early-exit).

Compressed KV pools (core/kv_quant.py: ``kv_format`` "int8" / "sc")
dequantize INSIDE the kernels: the per-position scale pools (and the sc
residual pools) ride the same scalar-prefetch page-table index maps as
the KV blocks, so each grid step DMAs one page of int8 codes plus its
scales and reconstructs float K/V in VMEM — the fp window never exists
in HBM.  The elementwise dequant mirrors ``kv_dequant`` exactly, so the
kernel-vs-reference differential stays as tight as the fp one.

Layout notes for real TPUs: the accumulator blocks put the (small) GQA
group width G in the lane dimension, so Mosaic pads tiles for the tiny
serving configs exercised here — fine for correctness-first; the
autotune sweep (kernels/autotune.py) picks ``num_splits`` / ``block_q``
per shape.  Interpret mode (`interpret=True`) is bit-for-bit the
compiled semantics and is what CPU CI runs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.kv_quant import SC_SHIFT, check_kv_format
from .plan import BlockOperand, LaunchPlan, ScalarOperand, call_plan

__all__ = ["paged_attn_decode_pallas", "paged_attn_prefill_pallas",
           "paged_attn_decode_plan", "paged_attn_prefill_plan"]

_NEG = -1e30


def _load_kv_block(kv_format: str, x_ref, s_ref=None, r_ref=None):
    """One physical page of K or V -> (page, D) float32, dequant fused.

    ``x_ref`` is the (1, page, 1, D) pool block; for compressed formats
    ``s_ref`` is the parallel (1, page, 1) scale block and ``r_ref`` the
    sc residual block.  The elementwise math mirrors
    core.kv_quant.kv_dequant exactly, so the kernel matches the
    gather-then-dequant reference bit-for-bit per element.
    """
    raw = x_ref[0, :, 0, :]
    if kv_format == "fp":
        return raw.astype(jnp.float32)
    sc = s_ref[0, :, 0]                             # (page,)
    if kv_format == "int8":
        return raw.astype(jnp.float32) * sc[:, None]
    fused = (r_ref[0, :, 0, :].astype(jnp.int32)
             + raw.astype(jnp.int32) * (1 << SC_SHIFT))
    return fused.astype(jnp.float32) * (sc * (2.0 ** -SC_SHIFT))[:, None]


def _split_aux_refs(kv_format: str, rest, n_tail: int):
    """Split a kernel's ``*rest`` refs into (aux_refs, tail_refs).

    pallas passes refs positionally: the format-dependent scale/resid
    blocks sit between the fixed inputs and the outputs/scratch, so the
    kernels take ``*rest`` and cut it here.  aux order: k_scale, v_scale
    [, k_resid, v_resid].
    """
    n_aux = {"fp": 0, "int8": 2, "sc": 4}[kv_format]
    assert len(rest) == n_aux + n_tail, (kv_format, len(rest), n_tail)
    return rest[:n_aux], rest[n_aux:]


# ---------------------------------------------------------------------------
# decode: one query row per slot, split-K over pages
# ---------------------------------------------------------------------------

def _decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref,
                   *rest, page: int, pps: int,
                   scale: float, kv_format: str):
    aux, (m_ref, l_ref, acc_ref) = _split_aux_refs(kv_format, rest, 3)
    s = pl.program_id(0)
    sp = pl.program_id(2)
    p = pl.program_id(3)

    @pl.when(p == 0)
    def _init():                                    # fresh (s, h, split)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[s]
    base = (sp * pps + p) * page                    # first position in page

    @pl.when(base <= length)                        # page holds live tokens
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)         # (G, D)
        k = _load_kv_block(kv_format, k_ref, *aux[0::2])   # (page, D)
        v = _load_kv_block(kv_format, v_ref, *aux[1::2])
        logits = jnp.dot(q, k.T) / scale            # (G, page)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        live = pos <= length                        # (1, page)
        logits = jnp.where(live, logits, _NEG)
        m_prev = m_ref[0, 0, 0]                     # (G,)
        new_m = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        w = jnp.where(live, jnp.exp(logits - new_m[:, None]), 0.0)
        corr = jnp.exp(m_prev - new_m)
        m_ref[0, 0, 0] = new_m
        l_ref[0, 0, 0] = l_ref[0, 0, 0] * corr + jnp.sum(w, axis=-1)
        acc_ref[0, 0, 0] = (acc_ref[0, 0, 0] * corr[:, None]
                            + jnp.dot(w, v))


def paged_attn_decode_plan(*, S: int, Hkv: int, G: int, D: int,
                           page: int, maxp: int, num_pages: int,
                           num_splits: int = 1, kv_format: str = "fp",
                           q_dtype=jnp.float32,
                           kv_dtype=None) -> LaunchPlan:
    """Static launch geometry of the flash-decoding decode kernel.

    Single source of truth for the grid, BlockSpecs and scalar-prefetch
    operands: :func:`paged_attn_decode_pallas` executes exactly this
    plan, and the static auditor (``repro.analysis.kernel_audit``)
    proves its bounds/VMEM/revisit properties without tracing it.
    ``num_pages`` is the pool's leading dim (engine pools include the
    reserved trash page), bounding legal page-table entries; ragged
    worst-case lengths straddle the last page boundary.
    """
    check_kv_format(kv_format)
    if kv_dtype is None:
        kv_dtype = jnp.float32 if kv_format == "fp" else jnp.int8
    num_splits = max(1, min(num_splits, maxp))
    pps = -(-maxp // num_splits)                    # pages per split
    maxp_pad = num_splits * pps                     # trash-padded lanes

    kernel = functools.partial(_decode_kernel, page=page, pps=pps,
                               scale=math.sqrt(D), kv_format=kv_format)

    def kv_index(s, h, sp, p, pt, ln):
        del ln
        return (pt[s, sp * pps + p], 0, h, 0)

    def scale_index(s, h, sp, p, pt, ln):
        del ln
        return (pt[s, sp * pps + p], 0, h)

    kv = dict(shape=(num_pages, page, Hkv, D), dtype=kv_dtype,
              block=(1, page, 1, D), index_map=kv_index)
    sc = dict(shape=(num_pages, page, Hkv), dtype=jnp.float32,
              block=(1, page, 1), index_map=scale_index)
    inputs = [
        BlockOperand("q", (S, Hkv, G, D), q_dtype, (1, 1, G, D),
                     lambda s, h, sp, p, pt, ln: (s, h, 0, 0)),
        BlockOperand("k_pages", **kv),
        BlockOperand("v_pages", **kv),
    ]
    if kv_format != "fp":
        inputs += [BlockOperand("k_scale", **sc),
                   BlockOperand("v_scale", **sc)]
    if kv_format == "sc":
        inputs += [BlockOperand("k_resid", **kv),
                   BlockOperand("v_resid", **kv)]

    part_index = lambda s, h, sp, p, pt, ln: (s, h, sp, 0)  # noqa: E731
    max_len = maxp * page
    return LaunchPlan(
        name="paged_attn_decode",
        grid=(S, Hkv, num_splits, pps),
        scalars=(
            ScalarOperand("page_tables", (S, maxp_pad), jnp.int32,
                          max_value=num_pages - 1),
            # the just-scattered token sits AT lengths, so legal values
            # are < max_len; worst cases straddle the last page
            # boundary: plen = length+1 with plen % page in {0,1,page-1}
            ScalarOperand("lengths", (S,), jnp.int32,
                          max_value=max_len - 1,
                          values=(max_len - page, max_len - page + 1,
                                  max(0, max_len - page - 1)),
                          kernel_only=True),
        ),
        inputs=tuple(inputs),
        outputs=(
            BlockOperand("m", (S, Hkv, num_splits, G), jnp.float32,
                         (1, 1, 1, G), part_index),
            BlockOperand("l", (S, Hkv, num_splits, G), jnp.float32,
                         (1, 1, 1, G), part_index),
            BlockOperand("acc", (S, Hkv, num_splits, G, D), jnp.float32,
                         (1, 1, 1, G, D),
                         lambda s, h, sp, p, pt, ln: (s, h, sp, 0, 0)),
        ),
        scratch=(),
        kernel=kernel,
        # the pps axis revisits each partial block only when a split
        # spans more than one page; with pps == 1 every block is written
        # exactly once (the @pl.when(p == 0) init always fires) and an
        # accumulate declaration would be stale metadata
        accumulate=({"m": "online-softmax", "l": "online-softmax",
                     "acc": "online-softmax"} if pps > 1 else {}),
        single_output=False,
    )


@functools.partial(jax.jit,
                   static_argnames=("num_splits", "interpret", "kv_format"))
def paged_attn_decode_pallas(q: jax.Array, k_pages: jax.Array,
                             v_pages: jax.Array, page_tables: jax.Array,
                             lengths: jax.Array, *, num_splits: int = 1,
                             interpret: bool = False,
                             kv_format: str = "fp",
                             k_scale: jax.Array | None = None,
                             v_scale: jax.Array | None = None,
                             k_resid: jax.Array | None = None,
                             v_resid: jax.Array | None = None) -> jax.Array:
    """Batched one-token paged decode.

    q: (S, Hkv, G, D) grouped queries; k_pages/v_pages: (N, page, Hkv, D)
    pools (already holding the new token at position ``lengths``);
    page_tables: (S, maxp) int32; lengths: (S,) int32.  For compressed
    pools (``kv_format`` "int8"/"sc") the parallel ``k_scale``/``v_scale``
    (N, page, Hkv) — and for sc the ``k_resid``/``v_resid`` — pools ride
    the SAME page-table index maps as the KV blocks, so each grid step
    DMAs one page of codes + its scales and dequantizes in VMEM: no fp
    pages ever materialize in HBM.  Returns the attention context
    (S, Hkv, G, D) in q.dtype.
    """
    check_kv_format(kv_format)
    S, Hkv, G, D = q.shape
    page = k_pages.shape[1]
    maxp = page_tables.shape[1]
    plan = paged_attn_decode_plan(
        S=S, Hkv=Hkv, G=G, D=D, page=page, maxp=maxp,
        num_pages=k_pages.shape[0], num_splits=num_splits,
        kv_format=kv_format, q_dtype=q.dtype, kv_dtype=k_pages.dtype)
    maxp_pad = plan.scalars[0].shape[1]
    if maxp_pad != maxp:
        # pad table lanes with the trash page: they sit past ``lengths``
        # (which is < maxp*page by construction) so masking kills them
        page_tables = jnp.pad(page_tables, ((0, 0), (0, maxp_pad - maxp)))

    aux_ops = []
    if kv_format != "fp":
        aux_ops += [k_scale, v_scale]
    if kv_format == "sc":
        aux_ops += [k_resid, v_resid]
    m, l, acc = call_plan(plan, (page_tables, lengths, q, k_pages,
                                 v_pages, *aux_ops), interpret=interpret)

    # flash-decoding LSE merge across splits (exact: splits with no live
    # pages carry m=-1e30, l=0 and weigh zero)
    m_star = jnp.max(m, axis=2)                       # (S, Hkv, G)
    alpha = jnp.exp(m - m_star[:, :, None])           # (S, Hkv, splits, G)
    l_tot = jnp.sum(l * alpha, axis=2)
    o = jnp.sum(acc * alpha[..., None], axis=2)
    o = o / jnp.maximum(l_tot, 1e-30)[..., None]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# prefill: chunk-aligned causal window over the pages written so far
# ---------------------------------------------------------------------------

def _prefill_kernel(pt_ref, q_ref, k_ref, v_ref,
                    *rest, bq: int, page: int, n_pg: int,
                    start: int, scale: float, kv_format: str):
    aux, (o_ref, m_sc, l_sc, acc_sc) = _split_aux_refs(kv_format, rest, 4)
    qi = pl.program_id(1)
    pg = pl.program_id(2)

    @pl.when(pg == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, _NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_hi = start + (qi + 1) * bq - 1                # last q position

    @pl.when(pg * page <= q_hi)                     # causal early-exit
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)            # (bq, D)
        k = _load_kv_block(kv_format, k_ref, *aux[0::2])   # (page, D)
        v = _load_kv_block(kv_format, v_ref, *aux[1::2])
        logits = jnp.dot(q, k.T) / scale            # (bq, page)
        q_pos = start + qi * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, page), 0)
        k_pos = pg * page + jax.lax.broadcasted_iota(
            jnp.int32, (bq, page), 1)
        causal = k_pos <= q_pos
        logits = jnp.where(causal, logits, _NEG)
        m_prev = m_sc[:, 0]                         # (bq,)
        new_m = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        w = jnp.where(causal, jnp.exp(logits - new_m[:, None]), 0.0)
        corr = jnp.exp(m_prev - new_m)
        m_sc[:, 0] = new_m
        l_sc[:, 0] = l_sc[:, 0] * corr + jnp.sum(w, axis=-1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jnp.dot(w, v)

    @pl.when(pg == n_pg - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...] /
                    jnp.maximum(l_sc[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_attn_prefill_plan(*, G: int, C: int, Hkv: int, Gq: int, D: int,
                            page: int, start: int, num_pages: int,
                            table_width: int | None = None,
                            block_q: int = 32, kv_format: str = "fp",
                            q_dtype=jnp.float32,
                            kv_dtype=None) -> LaunchPlan:
    """Static launch geometry of the chunked-prefill kernel (see
    :func:`paged_attn_decode_plan` for the contract).  ``table_width``
    is the page-table lane count the engine passes (>= pages seen so
    far); only lanes ``[0, (start+C)/page)`` are ever indexed."""
    check_kv_format(kv_format)
    if kv_dtype is None:
        kv_dtype = jnp.float32 if kv_format == "fp" else jnp.int8
    assert C % page == 0 and start % page == 0, (C, page, start)
    Hq = Hkv * Gq
    n_pg = (start + C) // page                      # pages seen so far
    if table_width is None:
        table_width = n_pg
    assert table_width >= n_pg, (table_width, n_pg)
    bq = min(block_q, C)
    if C % bq:
        bq = math.gcd(C, bq)

    kernel = functools.partial(_prefill_kernel, bq=bq, page=page,
                               n_pg=n_pg, start=start,
                               scale=math.sqrt(D), kv_format=kv_format)

    def kv_index(bh, qi, pg, pt):
        return (pt[bh // Hq, pg], 0, (bh % Hq) // Gq, 0)

    def scale_index(bh, qi, pg, pt):
        return (pt[bh // Hq, pg], 0, (bh % Hq) // Gq)

    kv = dict(shape=(num_pages, page, Hkv, D), dtype=kv_dtype,
              block=(1, page, 1, D), index_map=kv_index)
    sc = dict(shape=(num_pages, page, Hkv), dtype=jnp.float32,
              block=(1, page, 1), index_map=scale_index)
    inputs = [
        BlockOperand("q", (G * Hq, C, D), q_dtype, (1, bq, D),
                     lambda bh, qi, pg, pt: (bh, qi, 0)),
        BlockOperand("k_pages", **kv),
        BlockOperand("v_pages", **kv),
    ]
    if kv_format != "fp":
        inputs += [BlockOperand("k_scale", **sc),
                   BlockOperand("v_scale", **sc)]
    if kv_format == "sc":
        inputs += [BlockOperand("k_resid", **kv),
                   BlockOperand("v_resid", **kv)]

    return LaunchPlan(
        name="paged_attn_prefill",
        grid=(G * Hq, C // bq, n_pg),
        scalars=(
            ScalarOperand("page_tables", (G, table_width), jnp.int32,
                          max_value=num_pages - 1),
        ),
        inputs=tuple(inputs),
        outputs=(
            BlockOperand("o", (G * Hq, C, D), q_dtype, (1, bq, D),
                         lambda bh, qi, pg, pt: (bh, qi, 0)),
        ),
        scratch=(((bq, 1), jnp.float32),
                 ((bq, 1), jnp.float32),
                 ((bq, D), jnp.float32)),
        kernel=kernel,
        # every page revisits the same o block; (m,l,acc) live in VMEM
        # scratch and o is written once, under @pl.when(last page) — a
        # single-page launch writes each block exactly once
        accumulate=({"o": "scratch-finalize"} if n_pg > 1 else {}),
        single_output=True,
    )


@functools.partial(jax.jit,
                   static_argnames=("start", "block_q", "interpret",
                                    "kv_format"))
def paged_attn_prefill_pallas(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, page_tables: jax.Array,
                              *, start: int, block_q: int = 32,
                              interpret: bool = False,
                              kv_format: str = "fp",
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None,
                              k_resid: jax.Array | None = None,
                              v_resid: jax.Array | None = None) -> jax.Array:
    """One prefill chunk attending over the paged cache.

    q: (G, C, Hkv, Gq, D) — chunk ``[start, start + C)`` of each request
    in the admission group, C a multiple of the page size and ``start``
    chunk-aligned (both static); pools: (N, page, Hkv, D), already
    holding the chunk's whole-page K/V scatter; page_tables: (G, maxp).
    Compressed pools dequantize in VMEM through the same page-table
    index maps (see :func:`paged_attn_decode_pallas`).  Returns the
    context (G, C, Hkv, Gq, D) in q.dtype.  The causal mask matches the
    reference exactly: ``k_pos <= start + q_row``.
    """
    check_kv_format(kv_format)
    G, C, Hkv, Gq, D = q.shape
    page = k_pages.shape[1]
    Hq = Hkv * Gq
    plan = paged_attn_prefill_plan(
        G=G, C=C, Hkv=Hkv, Gq=Gq, D=D, page=page, start=start,
        num_pages=k_pages.shape[0], table_width=page_tables.shape[1],
        block_q=block_q, kv_format=kv_format, q_dtype=q.dtype,
        kv_dtype=k_pages.dtype)

    # head-major (G*Hq, C, D): program bh serves q head bh % Hq of
    # request bh // Hq; its KV head is (bh % Hq) // Gq (GQA grouping as
    # in flash_attention's kv index map)
    qh = jnp.moveaxis(q.reshape(G, C, Hq, D), 2, 1).reshape(G * Hq, C, D)

    aux_ops = []
    if kv_format != "fp":
        aux_ops += [k_scale, v_scale]
    if kv_format == "sc":
        aux_ops += [k_resid, v_resid]
    out = call_plan(plan, (page_tables, qh, k_pages, v_pages, *aux_ops),
                    interpret=interpret)
    out = jnp.moveaxis(out.reshape(G, Hq, C, D), 1, 2)
    return out.reshape(G, C, Hkv, Gq, D)

"""Pallas TPU kernel: bitonic sorting network over bit vectors.

The circuit-fidelity path of the BSN (DESIGN.md §2): each compare-exchange
level of Batcher's network becomes one VPU min/max over a VMEM-resident
tile — the sort never leaves VMEM.  The compare-exchange at distance j is
expressed as a reshape to (rows, L/2j, 2, j) + elementwise min/max (TPU has
no efficient gather; the reshape form keeps everything lane-aligned).

Grid: rows are tiled by ``block_r``; the full (power-of-two) sort length L
stays resident.  VMEM at defaults: block_r=256 rows x L=4096 lanes x int8
= 1 MiB + the same for the output — comfortable, and the log^2(L) levels
(78 for L=4096) all reuse the same tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsn_sort_pallas"]


def _sort_kernel(x_ref, o_ref, *, length: int, descending: bool):
    x = x_ref[...]                                   # (block_r, L)
    rows = x.shape[0]
    n_bits = length.bit_length() - 1
    for k_bit in range(1, n_bits + 1):               # merge size k = 2^k_bit
        k = 1 << k_bit
        for j_bit in range(k_bit - 1, -1, -1):       # distance j = 2^j_bit
            j = 1 << j_bit
            blocks = length // (2 * j)
            xr = x.reshape(rows, blocks, 2, j)
            a = xr[:, :, 0, :]
            b = xr[:, :, 1, :]
            # direction per 2j-block: bit k of the block start position
            starts = jnp.arange(blocks, dtype=jnp.int32) * (2 * j)
            up = (starts & k) == 0                   # (blocks,)
            keep_hi = up if descending else ~up
            keep_hi = keep_hi[None, :, None]
            hi = jnp.maximum(a, b)
            lo = jnp.minimum(a, b)
            first = jnp.where(keep_hi, hi, lo)
            second = jnp.where(keep_hi, lo, hi)
            x = jnp.stack([first, second], axis=2).reshape(rows, length)
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("descending", "block_r",
                                              "interpret"))
def bsn_sort_pallas(x: jax.Array, *, descending: bool = True,
                    block_r: int = 256, interpret: bool = False) -> jax.Array:
    """Sort each row of ``x`` (R, L). L must be a power of two; R a multiple
    of block_r (ops.py pads both)."""
    r, length = x.shape
    assert length & (length - 1) == 0, f"L={length} must be a power of 2"
    assert r % block_r == 0, (r, block_r)
    kernel = functools.partial(_sort_kernel, length=length,
                               descending=descending)
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel",))
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        kernel,
        grid=(r // block_r,),
        in_specs=[pl.BlockSpec((block_r, length), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_r, length), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, length), x.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(x)

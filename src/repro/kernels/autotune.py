"""Block-size autotuning for the Pallas kernels.

Tiny deterministic sweeps over the kernels' static tiling knobs —
split-K width (``num_splits``) for paged decode, q-block rows for paged
prefill, row-block size for the approximate BSN — timing each candidate
on synthetic data of the caller's shape and reporting the winner.  The
bench scripts (benchmarks/bench_serving.py, bench_approx_bsn.py) run
these per serving shape and record the winners into the root-level
BENCH JSONs, so successive PRs can compare tile choices, not just
end-to-end numbers.

Timing here is wall-clock over jitted calls with ``block_until_ready``
— on this CPU container that measures the interpret path (dispatch
overhead + interpreter), which is the comparable-correctness trajectory
the bench JSONs track; on a real TPU the same sweep times Mosaic.

Candidates are vetted *before* they are compiled: each sweep builds the
kernel's :class:`~repro.kernels.plan.LaunchPlan` for the candidate knobs
and skips any whose static VMEM estimate exceeds the audit budget — the
same estimate the ``vmem`` pass of ``repro.analysis.kernel_audit``
gates on, so the tuner can never crown a config the auditor would
reject.  Winners carry their ``vmem_est`` in the BENCH JSONs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_quant import check_kv_format, kv_quant

from .approx_bsn import approx_bsn_pallas, approx_bsn_plan
from .paged_attention import (paged_attn_decode_pallas,
                              paged_attn_decode_plan,
                              paged_attn_prefill_pallas,
                              paged_attn_prefill_plan)
from .plan import DEFAULT_VMEM_BUDGET, estimate_vmem

__all__ = ["time_callable", "sweep", "autotune_paged_decode",
           "autotune_paged_prefill", "autotune_approx_bsn"]


def time_callable(fn, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (fn is nullary, jitted)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def sweep(build, candidates: dict, *, iters: int = 10, plan_for=None,
          vmem_budget: int = DEFAULT_VMEM_BUDGET) -> dict:
    """Time ``build(**kwargs)`` for each candidate; pick the fastest.

    candidates: {label: kwargs}.  Returns {"winner": label,
    "us_per_call": {label: us}} — the stable schema the BENCH JSONs
    carry per shape.

    ``plan_for(**kwargs)`` (optional) returns the candidate's
    :class:`~repro.kernels.plan.LaunchPlan`; candidates whose
    ``estimate_vmem`` exceeds ``vmem_budget`` are *pruned* — never
    compiled or timed — and land in the report's ``"pruned"`` map
    instead.  Surviving candidates carry ``"vmem_est"``.  If every
    candidate is over budget the cheapest one runs anyway (flagged as
    ``"all_over_budget"``) so the sweep still returns a winner.
    """
    vmem_est, pruned, all_over = {}, {}, False
    if plan_for is not None:
        for label, kw in candidates.items():
            vmem_est[label] = estimate_vmem(plan_for(**kw))
        pruned = {l: b for l, b in vmem_est.items() if b > vmem_budget}
        if candidates and len(pruned) == len(candidates):
            all_over = True
            del pruned[min(pruned, key=pruned.get)]
    table = {}
    for label, kw in candidates.items():
        if label in pruned:
            continue
        table[label] = round(time_callable(build(**kw), iters=iters), 2)
    winner = min(table, key=table.get)
    out = {"winner": winner, "us_per_call": table}
    if plan_for is not None:
        out["vmem_est"] = {l: vmem_est[l] for l in table}
        if pruned:
            out["pruned"] = pruned
        if all_over:
            out["all_over_budget"] = True
    return out


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _paged_case(seed, S, Hkv, D, page, maxp, kv_format="fp"):
    """Synthetic pools + tables for one paged shape.  For compressed
    formats the float pools are quantized positionwise, yielding the
    code pages and the aux (scale / residual) operand dict the kernels
    take — so the sweep times the fused-dequant kernel, not a float
    stand-in."""
    check_kv_format(kv_format)
    rng = np.random.default_rng(seed)
    n = S * maxp + 1
    kf = jnp.asarray(rng.standard_normal((n, page, Hkv, D)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((n, page, Hkv, D)), jnp.float32)
    aux = {}
    if kv_format == "fp":
        kp, vp = kf, vf
    else:
        kq, vq = kv_quant(kf, kv_format), kv_quant(vf, kv_format)
        kp, vp = kq["q"], vq["q"]
        aux = {"k_scale": kq["scale"], "v_scale": vq["scale"]}
        if kv_format == "sc":
            aux |= {"k_resid": kq["resid"], "v_resid": vq["resid"]}
    tables = np.zeros((S, maxp), np.int32)
    for s in range(S):
        tables[s] = 1 + s * maxp + rng.permutation(maxp)
    return rng, kp, vp, jnp.asarray(tables), aux


def autotune_paged_decode(S: int, Hkv: int, G: int, D: int, page: int,
                          maxp: int, *, splits=(1, 2, 4),
                          kv_format: str = "fp",
                          iters: int = 10) -> dict:
    """Sweep the flash-decoding split-K width for one decode shape."""
    rng, kp, vp, tables, aux = _paged_case(0, S, Hkv, D, page, maxp,
                                           kv_format)
    q = jnp.asarray(rng.standard_normal((S, Hkv, G, D)), jnp.float32)
    lengths = jnp.asarray(rng.integers(0, maxp * page, S), jnp.int32)
    interp = _interpret()

    def build(num_splits):
        return lambda: paged_attn_decode_pallas(
            q, kp, vp, tables, lengths, num_splits=num_splits,
            interpret=interp, kv_format=kv_format, **aux)

    def plan_for(num_splits):
        return paged_attn_decode_plan(
            S=S, Hkv=Hkv, G=G, D=D, page=page, maxp=maxp,
            num_pages=kp.shape[0], num_splits=num_splits,
            kv_format=kv_format)

    cands = {f"num_splits={s}": {"num_splits": s}
             for s in splits if s <= maxp}
    out = sweep(build, cands, iters=iters, plan_for=plan_for)
    out["shape"] = dict(S=S, Hkv=Hkv, G=G, D=D, page=page, maxp=maxp,
                        kv_format=kv_format)
    return out


def autotune_paged_prefill(G: int, C: int, Hkv: int, Gq: int, D: int,
                           page: int, start: int, *,
                           block_qs=(8, 16, 32),
                           kv_format: str = "fp",
                           iters: int = 10) -> dict:
    """Sweep the q-block rows for one chunked-prefill shape."""
    maxp = (start + C) // page
    rng, kp, vp, tables, aux = _paged_case(1, G, Hkv, D, page, maxp,
                                           kv_format)
    q = jnp.asarray(rng.standard_normal((G, C, Hkv, Gq, D)), jnp.float32)
    interp = _interpret()

    def build(block_q):
        return lambda: paged_attn_prefill_pallas(
            q, kp, vp, tables, start=start, block_q=block_q,
            interpret=interp, kv_format=kv_format, **aux)

    def plan_for(block_q):
        return paged_attn_prefill_plan(
            G=G, C=C, Hkv=Hkv, Gq=Gq, D=D, page=page, start=start,
            num_pages=kp.shape[0], table_width=tables.shape[1],
            block_q=block_q, kv_format=kv_format)

    cands = {f"block_q={b}": {"block_q": b} for b in block_qs if b <= C}
    out = sweep(build, cands, iters=iters, plan_for=plan_for)
    out["shape"] = dict(G=G, C=C, Hkv=Hkv, Gq=Gq, D=D, page=page,
                        start=start, kv_format=kv_format)
    return out


def autotune_approx_bsn(rows: int, spec, *, block_rs=(64, 128, 256),
                        iters: int = 10) -> dict:
    """Sweep the BSN kernel's row-block size for one (rows, spec) shape."""
    from .dispatch import spec_stages                 # lazy: no cycle
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(0, spec.in_bsl + 1, (rows, spec.width)),
                    jnp.int32)
    interp = _interpret()
    stages = spec_stages(spec)

    def build(block_r):
        br = min(block_r, max(8, 1 << (rows - 1).bit_length()))
        rp = (rows + br - 1) // br * br
        xp = jnp.pad(x, ((0, rp - rows), (0, 0)))
        return lambda: approx_bsn_pallas(xp, in_bsl=spec.in_bsl,
                                         stages=stages, block_r=br,
                                         interpret=interp)

    def plan_for(block_r):
        br = min(block_r, max(8, 1 << (rows - 1).bit_length()))
        rp = (rows + br - 1) // br * br
        return approx_bsn_plan(rows=rp, width=spec.width,
                               in_bsl=spec.in_bsl, stages=stages,
                               block_r=br)

    cands = {f"block_r={b}": {"block_r": b} for b in block_rs}
    out = sweep(build, cands, iters=iters, plan_for=plan_for)
    out["shape"] = dict(rows=rows, width=spec.width, in_bsl=spec.in_bsl)
    return out

"""Public jit'd wrappers for the Pallas kernels.

Handle batching, ragged shapes (padding to block multiples), backend
selection (interpret=True on CPU so the kernels validate bit-for-bit in
this container, compiled path on real TPU), and small-shape fallbacks to
the jnp reference (a 16x16 matmul doesn't deserve a pallas_call).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bsn_sort import bsn_sort_pallas
from .ternary_matmul import ternary_matmul_pallas

__all__ = ["ternary_matmul", "bsn_sort", "use_interpret"]

_FORCE_INTERPRET: bool | None = None


def use_interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def ternary_matmul(x_q: jax.Array, w_int: jax.Array,
                   thresholds_q: jax.Array | None = None,
                   *, block_m: int = 256, block_n: int = 256,
                   block_k: int = 512,
                   min_flops_for_kernel: int = 2 ** 22) -> jax.Array:
    """SC integer datapath matmul: (..., K) x (K, N) -> (..., N) int32.

    ``x_q``: int8 activation levels; ``w_int``: int8 ternary weights;
    ``thresholds_q``: optional (N, out_bsl) SI table (q domain).
    """
    *batch, k = x_q.shape
    k2, n = w_int.shape
    assert k == k2, (x_q.shape, w_int.shape)
    m = int(np.prod(batch)) if batch else 1

    if 2 * m * n * k < min_flops_for_kernel:
        return ref.ternary_matmul_ref(x_q, w_int, thresholds_q)

    x2 = x_q.reshape(m, k)
    mp, np_, kp = (_round_up(m, block_m), _round_up(n, block_n),
                   _round_up(k, block_k))
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    w2 = jnp.pad(w_int, ((0, kp - k), (0, np_ - n)))
    t2 = None
    if thresholds_q is not None:
        # padded output channels get a never-firing threshold table
        big = jnp.iinfo(jnp.int32).max
        t2 = jnp.pad(thresholds_q.astype(jnp.int32),
                     ((0, np_ - n), (0, 0)), constant_values=big)
    out = ternary_matmul_pallas(x2, w2, t2, block_m=block_m,
                                block_n=block_n, block_k=block_k,
                                interpret=use_interpret())
    out = out[:m, :n]
    return out.reshape(*batch, n) if batch else out[0]


def bsn_sort(bits: jax.Array, *, block_r: int = 256,
             min_rows_for_kernel: int = 8) -> jax.Array:
    """Descending bitonic sort of thermometer bit vectors (..., L).

    Pads L to the next power of two with 0s (they sink to the tail and are
    cropped — count-preserving for {0,1} bit inputs) and rows to block_r.
    """
    *batch, length = bits.shape
    r = int(np.prod(batch)) if batch else 1
    if r < min_rows_for_kernel:
        return ref.bsn_sort_ref(bits)

    lp = 1 << (length - 1).bit_length()
    rp = _round_up(r, block_r)
    x2 = bits.reshape(r, length)
    x2 = jnp.pad(x2, ((0, rp - r), (0, lp - length)))
    out = bsn_sort_pallas(x2, descending=True, block_r=block_r,
                          interpret=use_interpret())
    out = out[:r, :length]
    return out.reshape(*batch, length) if batch else out[0]

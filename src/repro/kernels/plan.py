"""Introspectable launch plans for the Pallas kernel fleet.

Every kernel in this package used to build its ``pl.pallas_call``
inline, which made the launch geometry — grid, BlockSpec index maps,
block shapes, scratch, scalar-prefetch operands — invisible to anything
but the Pallas tracer.  The static kernel auditor
(``repro.analysis.kernel_audit``) needs exactly that geometry *without*
tracing, so each kernel now factors its launch into a
:class:`LaunchPlan` built by a pure-Python ``*_plan(...)`` function of
the static shapes.  The same plan object drives the real launch
(:func:`call_plan`) and the audit passes, so the audited geometry can
never drift from the executed one.

A plan records, per operand, the full array shape, the block shape and
the index map (the exact Python callable handed to ``pl.BlockSpec``),
plus — for scalar-prefetch operands — a *worst-case value model*: the
inclusive bound on legal entries (``max_value``) and any extra
adversarial fill values (``values``, e.g. ragged lengths straddling a
page boundary).  The auditor enumerates index maps over the full grid
with scalars pinned to those extremes; because every index map in this
fleet is elementwise monotone in its scalar entries, the extremes are a
proof, not a sample (analysis/README.md "kernel audit").

``accumulate`` declares the write discipline of every output block that
is *revisited* (written from more than one grid step): the revisit pass
cross-checks the declaration against the actual output index maps and
against the kernel body (a revisited block whose kernel never guards a
first write with ``pl.when`` is silent last-write-wins).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["BlockOperand", "ScalarOperand", "LaunchPlan", "call_plan",
           "estimate_vmem", "compiler_params", "kernel_source_fn",
           "DEFAULT_VMEM_BUDGET"]

# ~16 MiB of VMEM per TPU core (v4/v5 class); the audit budget leaves
# headroom for Mosaic's own spills by defaulting to half of it
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20


@dataclass(frozen=True)
class BlockOperand:
    """One blocked (non-scalar-prefetch) input or output operand."""
    name: str
    shape: tuple[int, ...]              # full operand shape
    dtype: Any                          # jnp dtype of the HBM buffer
    block: tuple[int, ...]              # BlockSpec block shape
    index_map: Callable                 # (grid..., *scalar_refs) -> blocks

    def block_bytes(self) -> int:
        return math.prod(self.block) * jnp.dtype(self.dtype).itemsize


@dataclass(frozen=True)
class ScalarOperand:
    """One scalar-prefetch operand plus its worst-case value model.

    ``max_value`` is the inclusive upper bound on legal entries (page
    tables: ``num_pages - 1``; lengths: ``max_len - 1``).  ``values``
    adds adversarial fills beyond the {0, max_value} extremes — e.g.
    lengths whose live prefix straddles a page boundary
    (``plen % page in {0, 1, page-1}``).  ``kernel_only`` marks operands
    read by the kernel body but never by an index map (per-slot lengths
    drive masking, not DMA), so the grid pass does not flag them unused.
    """
    name: str
    shape: tuple[int, ...]
    dtype: Any
    max_value: int
    values: tuple[int, ...] = ()
    kernel_only: bool = False


@dataclass(frozen=True)
class LaunchPlan:
    """Complete static geometry of one ``pl.pallas_call`` launch."""
    name: str
    grid: tuple[int, ...]
    scalars: tuple[ScalarOperand, ...]
    inputs: tuple[BlockOperand, ...]
    outputs: tuple[BlockOperand, ...]
    scratch: tuple[tuple[tuple[int, ...], Any], ...]
    kernel: Callable
    # output name -> declared write discipline for revisited blocks
    # ("online-softmax" | "when-init-accumulate" | "scratch-finalize")
    accumulate: dict[str, str] = field(default_factory=dict)
    dimension_semantics: tuple[str, ...] | None = None
    single_output: bool = True

    def scratch_bytes(self) -> int:
        return sum(math.prod(s) * jnp.dtype(d).itemsize
                   for s, d in self.scratch)


def estimate_vmem(plan: LaunchPlan) -> int:
    """Per-program VMEM estimate in bytes: every input/output block is
    double-buffered by the Pallas pipeline (x2), scratch is resident
    once.  Register-resident temporaries (e.g. the dequantized f32 copy
    of an int8 KV block) are deliberately excluded — the estimate bounds
    the DMA working set, which is what blows up first when a block knob
    (num_splits / block_q / block_r) is oversized."""
    blocks = sum(op.block_bytes() for op in plan.inputs + plan.outputs)
    return 2 * blocks + plan.scratch_bytes()


def compiler_params(semantics: tuple[str, ...]):
    """dimension_semantics across the jax naming change."""
    try:
        return pltpu.CompilerParams(dimension_semantics=semantics)
    except AttributeError:                           # older jax naming
        return pltpu.TPUCompilerParams(dimension_semantics=semantics)


def call_plan(plan: LaunchPlan, operands: tuple, *,
              interpret: bool = False):
    """Execute a plan: scalars first, then blocked inputs, exactly the
    ``pl.pallas_call`` the kernels used to build inline."""
    out_specs = [pl.BlockSpec(op.block, op.index_map)
                 for op in plan.outputs]
    out_shape = [jax.ShapeDtypeStruct(op.shape, op.dtype)
                 for op in plan.outputs]
    if plan.single_output:
        assert len(plan.outputs) == 1, plan.name
        out_specs, out_shape = out_specs[0], out_shape[0]
    in_specs = [pl.BlockSpec(op.block, op.index_map) for op in plan.inputs]
    kw = {}
    if plan.dimension_semantics is not None:
        kw["compiler_params"] = compiler_params(plan.dimension_semantics)
    if plan.scalars or plan.scratch:
        call = pl.pallas_call(
            plan.kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=len(plan.scalars),
                grid=plan.grid,
                in_specs=in_specs,
                out_specs=out_specs,
                scratch_shapes=[pltpu.VMEM(s, d) for s, d in plan.scratch],
            ),
            out_shape=out_shape,
            interpret=interpret,
            **kw)
    else:
        call = pl.pallas_call(
            plan.kernel,
            grid=plan.grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
            **kw)
    return call(*operands)


def kernel_source_fn(plan: LaunchPlan) -> Callable:
    """The underlying kernel function of a plan (unwrapping partials),
    for source-level discipline checks."""
    fn = plan.kernel
    while isinstance(fn, functools.partial):
        fn = fn.func
    return fn

"""Pallas TPU kernels for the SC datapath + framework hot-spots.

ternary_matmul  — int8 ternary matmul + fused SI epilogue (the SC
                  accelerator datapath, DESIGN.md §2); bit-exact vs
                  ref.ternary_matmul_ref and the circuit simulation.
bsn_sort        — bitonic sorting network as VPU compare-exchange levels.
flash_attention — fused online-softmax attention (serving path),
                  motivated by the §Perf memory-term attribution.
"""

from . import ops, ref
from .flash_attention import flash_attention_pallas
from .ops import bsn_sort, ternary_matmul

__all__ = ["ops", "ref", "bsn_sort", "ternary_matmul",
           "flash_attention_pallas"]

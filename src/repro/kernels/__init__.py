"""Pallas TPU kernels for the SC datapath + framework hot-spots.

ternary_matmul  — int8 ternary matmul + fused SI epilogue (the SC
                  accelerator datapath, DESIGN.md §2); bit-exact vs
                  ref.ternary_matmul_ref and the circuit simulation.
bsn_sort        — exact bitonic sorting network as VPU compare-exchange
                  levels (the paper's baseline adder).
approx_bsn      — fused approximate progressive-sorting BSN (Fig 10b)
                  plus the chunked temporal-reuse variant (Fig 12); the
                  paper's proposed hot path.
dispatch        — backend selection (pallas / pallas-interpret /
                  reference) for the approximate adder and the paged
                  attention; see README.md.
flash_attention — fused online-softmax attention (serving path),
                  motivated by the §Perf memory-term attribution.
paged_attention — flash-decoding paged decode + chunked paged prefill
                  reading KV pages through the page table (the
                  ServeEngine hot path; ROADMAP's raw-speed lever).
autotune        — block-size sweeps (split-K width, q blocks, BSN row
                  blocks) recorded into the root BENCH JSONs.
"""

# NOTE: dispatch.approx_bsn is deliberately NOT re-exported at package
# level — the name would shadow the kernels.approx_bsn submodule.  Call
# dispatch.approx_bsn or the core.bsn.approx_bsn front door instead.
# Ditto dispatch.paged_attn_* vs the kernels.paged_attention submodule.
from . import autotune, dispatch, ops, ref
from .approx_bsn import approx_bsn_pallas, approx_bsn_temporal_pallas
from .dispatch import attn_backend_scope, backend_scope
from .flash_attention import flash_attention_pallas
from .ops import bsn_sort, ternary_matmul
from .paged_attention import (paged_attn_decode_pallas,
                              paged_attn_prefill_pallas)

__all__ = ["autotune", "dispatch", "ops", "ref", "bsn_sort",
           "ternary_matmul", "approx_bsn_pallas",
           "approx_bsn_temporal_pallas", "backend_scope",
           "attn_backend_scope", "flash_attention_pallas",
           "paged_attn_decode_pallas", "paged_attn_prefill_pallas"]

"""Pallas TPU kernel: fused approximate progressive-sorting BSN.

The paper's efficient adder (§IV-B, Fig 10b) is a pipeline of sub-BSN
stages: group ``g_i`` partial thermometer codes, sort them, clip ``c_i``
bits off each tail (near-Gaussian inputs carry almost no tail mass,
Fig 11), then keep one of every ``s_i`` wires.  In the count domain —
proven equivalent to the bit-level circuit in core/bsn.py and re-proven
against this kernel in tests/test_approx_bsn_kernel.py — each stage is a
grouped integer sum followed by saturate + floor-divide, so the whole
pipeline fuses into one VMEM-resident pass over a (block_r, width) tile
of popcounts:

    per stage (group g, clip c, stride s), entering BSL L:
        x <- sum over groups of g            # sorted popcount
        x <- clamp(x - c, 0, g*L - 2c)       # tail clip (saturation)
        x <- (x + s//2) >> log2(s)           # sub-sample (pow2 strides)

Strides are powers of two in every paper design point (the output scale
``prod(s_i)`` must be re-alignable by the §III-C residual re-scaler), so
the divide lowers to a shift; non-pow2 strides fall back to integer
division (fine in interpret mode, compiler-expanded on TPU).

Two entry points:

``approx_bsn_pallas``           — spatial pipeline, one pass per row tile.
``approx_bsn_temporal_pallas``  — the Fig 12 temporal-reuse variant: a
    physically small BSN reused over ``cycles`` chunks.  The grid gains an
    ``arbitrary`` cycle dimension; each step runs the spatial pipeline on
    its (block_r, width) chunk and accumulates the short partial code into
    the revisited output block, exactly like the silicon's accumulator.

Both are parameterized by primitive static tuples ``stages = ((group,
clip, stride), ...)`` so this module stays free of core imports; the
dispatch layer (kernels/dispatch.py) converts ``core.bsn.ApproxBSNSpec``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .plan import BlockOperand, LaunchPlan, call_plan

__all__ = ["approx_bsn_pallas", "approx_bsn_temporal_pallas",
           "approx_bsn_plan", "approx_bsn_temporal_plan",
           "validate_stages"]

Stages = tuple[tuple[int, int, int], ...]


def validate_stages(width: int, in_bsl: int, stages: Stages) -> int:
    """Static shape-check of a primitive stage tuple; returns out_bsl."""
    n, bsl = width, in_bsl
    prod_g = 1
    for group, clip, stride in stages:
        prod_g *= group
        if n % group:
            raise ValueError(f"group {group} does not divide width {n}")
        n //= group
        sorted_len = bsl * group
        kept = sorted_len - 2 * clip
        if kept <= 0 or kept % stride:
            raise ValueError(f"clip={clip}, stride={stride} invalid for "
                             f"sorted length {sorted_len}")
        bsl = kept // stride
    if prod_g != width:
        raise ValueError(f"prod(groups)={prod_g} != width={width}")
    return bsl


def _pipeline(x: jax.Array, in_bsl: int, stages: Stages) -> jax.Array:
    """Count-domain progressive pipeline on the trailing axis.

    ``x``: (..., width) int32 popcounts -> (..., 1) output popcounts.
    Static Python loop: the stage structure unrolls at trace time, like
    the compare-exchange levels of bsn_sort.py.
    """
    bsl = in_bsl
    for group, clip, stride in stages:
        m = x.shape[-1] // group
        x = jnp.sum(x.reshape(x.shape[:-1] + (m, group)), axis=-1)
        sorted_len = bsl * group
        kept = sorted_len - 2 * clip
        # clamp unconditionally: the oracle (SubSampleSpec.apply_counts)
        # saturates even with clip=0, and out-of-range inputs must not
        # diverge between backends
        x = jnp.clip(x - clip, 0, kept)
        if stride > 1:
            phase = stride // 2
            if stride & (stride - 1) == 0:          # pow2: lower to a shift
                sh = stride.bit_length() - 1
                x = jax.lax.shift_right_logical(x + phase, sh)
            else:
                x = (x + phase) // stride
        bsl = kept // stride
    return x                                         # (..., 1)


def _spatial_kernel(c_ref, o_ref, *, in_bsl: int, stages: Stages):
    x = c_ref[...].astype(jnp.int32)                 # (block_r, width)
    o_ref[...] = _pipeline(x, in_bsl, stages)        # (block_r, 1)


def _temporal_kernel(c_ref, o_ref, *, in_bsl: int, stages: Stages):
    t = pl.program_id(1)
    part = _pipeline(c_ref[...].astype(jnp.int32), in_bsl, stages)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part

    @pl.when(t != 0)
    def _accum():
        o_ref[...] = o_ref[...] + part


def approx_bsn_plan(*, rows: int, width: int, in_bsl: int, stages: Stages,
                    block_r: int = 256) -> LaunchPlan:
    """Static launch geometry of the spatial BSN kernel: one
    (block_r, width) row tile per grid step, no revisits (the row axis
    is embarrassingly parallel).  ``rows`` must already be padded to a
    multiple of ``block_r`` (dispatch.py pads)."""
    validate_stages(width, in_bsl, stages)
    assert rows % block_r == 0, (rows, block_r)
    return LaunchPlan(
        name="approx_bsn_spatial",
        grid=(rows // block_r,),
        scalars=(),
        inputs=(BlockOperand("counts", (rows, width), jnp.int32,
                             (block_r, width), lambda i: (i, 0)),),
        outputs=(BlockOperand("out", (rows, 1), jnp.int32,
                              (block_r, 1), lambda i: (i, 0)),),
        scratch=(),
        kernel=functools.partial(_spatial_kernel, in_bsl=in_bsl,
                                 stages=stages),
        dimension_semantics=("parallel",),
    )


def approx_bsn_temporal_plan(*, rows: int, width: int, in_bsl: int,
                             stages: Stages, cycles: int,
                             block_r: int = 256) -> LaunchPlan:
    """Static launch geometry of the temporal-reuse (Fig 12) variant:
    the cycle axis revisits the same output block and accumulates under
    a ``@pl.when(t == 0)`` init, so it is declared ``arbitrary`` (a
    parallel cycle axis would be a write race)."""
    validate_stages(width, in_bsl, stages)
    assert rows % block_r == 0, (rows, block_r)
    return LaunchPlan(
        name="approx_bsn_temporal",
        grid=(rows // block_r, cycles),
        scalars=(),
        inputs=(BlockOperand("counts", (rows, cycles * width), jnp.int32,
                             (block_r, width), lambda i, t: (i, t)),),
        outputs=(BlockOperand("out", (rows, 1), jnp.int32,
                              (block_r, 1), lambda i, t: (i, 0)),),
        scratch=(),
        kernel=functools.partial(_temporal_kernel, in_bsl=in_bsl,
                                 stages=stages),
        accumulate={"out": "when-init-accumulate"},
        dimension_semantics=("parallel", "arbitrary"),
    )


@functools.partial(jax.jit, static_argnames=("in_bsl", "stages", "block_r",
                                             "interpret"))
def approx_bsn_pallas(counts: jax.Array, *, in_bsl: int, stages: Stages,
                      block_r: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Fused spatial approximate BSN on (R, width) int popcounts -> (R,).

    R must be a multiple of block_r (dispatch.py pads).  The entire
    pipeline runs in one pallas_call; nothing leaves VMEM between stages.
    """
    r, width = counts.shape
    plan = approx_bsn_plan(rows=r, width=width, in_bsl=in_bsl,
                           stages=stages, block_r=block_r)
    out = call_plan(plan, (counts,), interpret=interpret)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("in_bsl", "stages", "cycles",
                                             "block_r", "interpret"))
def approx_bsn_temporal_pallas(counts: jax.Array, *, in_bsl: int,
                               stages: Stages, cycles: int,
                               block_r: int = 256,
                               interpret: bool = False) -> jax.Array:
    """Temporal-reuse (Fig 12) variant on (R, cycles*width) -> (R,).

    Grid (rows, cycles): the cycle dimension revisits the same output
    block and accumulates, so VMEM only ever holds one (block_r, width)
    chunk — the kernel-level analogue of folding a wide accumulation onto
    a physically small BSN.
    """
    r, total = counts.shape
    assert total % cycles == 0, (total, cycles)
    width = total // cycles
    plan = approx_bsn_temporal_plan(rows=r, width=width, in_bsl=in_bsl,
                                    stages=stages, cycles=cycles,
                                    block_r=block_r)
    out = call_plan(plan, (counts,), interpret=interpret)
    return out[:, 0]

"""Pallas TPU kernel: the SC integer datapath (ternary matmul + SI epilogue).

This is the compute hot-spot of the paper's accelerator, adapted to the
TPU's memory hierarchy (DESIGN.md §2): the ternary-multiplier bank + BSN +
SI of one output tile become

    int8 activations (bm, bk) x int8 ternary weights (bk, bn)
      -> MXU int32 accumulate in VMEM scratch        (== BSN popcount)
      -> threshold-count epilogue                    (== SI wiring)

Tiling: grid (M/bm, N/bn, K/bk) with the K axis innermost ("arbitrary"
semantics) so the accumulator tile lives in VMEM across the contraction.
Block shapes default to MXU-aligned (128k) multiples; int8 operands allow
2x the bf16 MXU throughput on v5e.

VMEM budget at defaults (bm=256, bn=256, bk=512):
    x 256*512 + w 512*256 (int8)            = 0.25 MiB
    acc 256*256 int32 + out 256*256 int32   = 0.50 MiB
    thresholds 256*out_bsl(<=32) int32      = 0.03 MiB
well under the 16 MiB/core VMEM of v5e, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ternary_matmul_pallas"]


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    """Plain accumulate variant (no epilogue)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.int32),
                            w_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _matmul_si_kernel(x_ref, w_ref, t_ref, o_ref, acc_ref, *, out_bsl: int):
    """Fused SI epilogue: out_q = #{j: sum >= t_j} - out_bsl/2.

    The threshold loop is static (out_bsl <= 32) — it unrolls into out_bsl
    vectorized compares on the (bm, bn) accumulator tile, i.e. the SI is
    free relative to the MXU work exactly as the wiring is free in silicon.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.int32),
                            w_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...]
        counts = jnp.zeros_like(acc)
        for j in range(out_bsl):                       # static unroll
            tj = t_ref[:, j][None, :]                  # (1, bn)
            counts = counts + (acc >= tj).astype(jnp.int32)
        o_ref[...] = counts - out_bsl // 2


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret"))
def ternary_matmul_pallas(x_q: jax.Array, w_int: jax.Array,
                          thresholds_q: jax.Array | None = None,
                          *, block_m: int = 256, block_n: int = 256,
                          block_k: int = 512,
                          interpret: bool = False) -> jax.Array:
    """2-D core: x_q (M, K) int8 levels, w_int (K, N) int8 in {-1,0,1}.

    thresholds_q: optional (N, out_bsl) int32 SI table in the q domain.
    Shapes must already be padded to block multiples (ops.py handles
    ragged shapes and batching).
    """
    m, k = x_q.shape
    k2, n = w_int.shape
    assert k == k2, (x_q.shape, w_int.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    grid = (m // block_m, n // block_n, k // block_k)

    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except AttributeError:  # older pallas naming
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )
    x_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    w_spec = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))

    if thresholds_q is None:
        return pl.pallas_call(
            _matmul_kernel, in_specs=[x_spec, w_spec], **common,
        )(x_q, w_int)

    out_bsl = thresholds_q.shape[-1]
    t_spec = pl.BlockSpec((block_n, out_bsl), lambda i, j, kk: (j, 0))
    kernel = functools.partial(_matmul_si_kernel, out_bsl=out_bsl)
    return pl.pallas_call(
        kernel, in_specs=[x_spec, w_spec, t_spec], **common,
    )(x_q, w_int, thresholds_q)

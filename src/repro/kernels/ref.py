"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

``ternary_matmul_ref`` is additionally proven equivalent to the bit-exact
multiplier+BSN circuit simulation in tests/test_hwmodel_sc_layers.py, so
the chain  Pallas kernel == this oracle == the silicon datapath  is closed.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core.kv_quant import kv_dequant

__all__ = ["ternary_matmul_ref", "bsn_sort_ref", "si_epilogue_ref",
           "gather_pages", "gather_pages_dequant", "paged_attn_decode_ref",
           "paged_attn_prefill_ref", "paged_attn_verify_ref"]


def si_epilogue_ref(sum_q: jax.Array, thresholds_q: jax.Array) -> jax.Array:
    """SI activation on accumulated sums (q domain).

    thresholds_q: (N, out_bsl) int32, ascending along the last axis.
    out_q = #{j : sum_q >= t_j} - out_bsl/2.
    """
    t = thresholds_q.astype(jnp.int32)
    out_counts = jnp.sum(sum_q[..., None] >= t, axis=-1, dtype=jnp.int32)
    return out_counts - t.shape[-1] // 2


def ternary_matmul_ref(x_q: jax.Array, w_int: jax.Array,
                       thresholds_q: jax.Array | None = None) -> jax.Array:
    """int8 activation levels x int8 ternary weights -> int32 sums.

    Functional identity with the SC datapath: the int32 accumulate equals
    the BSN's sorted popcount (minus the fixed offset), and the optional
    epilogue is the SI wiring.
    """
    sum_q = jax.lax.dot_general(
        x_q.astype(jnp.int32), w_int.astype(jnp.int32),
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    if thresholds_q is None:
        return sum_q
    return si_epilogue_ref(sum_q, thresholds_q)


def bsn_sort_ref(bits: jax.Array) -> jax.Array:
    """Descending sort of the trailing axis (thermometer normal form)."""
    return jnp.sort(bits, axis=-1)[..., ::-1]


def gather_pages(pages: jax.Array, page_tables: jax.Array) -> jax.Array:
    """(N, page, ...) pool + (S, maxp) tables -> (S, maxp*page, ...).

    Works for KV pools (N, page, H, Dh) and their parallel scale pools
    (N, page, H) alike — the trailing axes ride along unchanged.
    """
    S, maxp = page_tables.shape
    page = pages.shape[1]
    g = jnp.take(pages, page_tables.reshape(-1), axis=0)
    return g.reshape(S, maxp * page, *pages.shape[2:])


def gather_pages_dequant(pages: jax.Array, page_tables: jax.Array, *,
                         kv_format: str = "fp", scale: jax.Array | None = None,
                         resid: jax.Array | None = None) -> jax.Array:
    """Gather + dequantize a compressed pool window in one step.

    Gather commutes with the elementwise dequant, so dequantizing the
    gathered window is bit-identical to gathering a dequantized pool —
    without ever materializing fp pages.  Zero-filled positions (trash
    page, unwritten tail) dequantize to exact 0 in every format.
    """
    g = gather_pages(pages, page_tables)
    if kv_format == "fp":
        return g
    sg = gather_pages(scale, page_tables)
    rg = gather_pages(resid, page_tables) if kv_format == "sc" else None
    return kv_dequant(g, sg, rg, fmt=kv_format)


def paged_attn_decode_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, page_tables: jax.Array,
                          lengths: jax.Array, *, pin_logits=None,
                          kv_format: str = "fp",
                          kv_aux: dict | None = None) -> jax.Array:
    """XLA gather/scatter paged decode — the paged-kernel ground truth.

    q: (S, Hkv, G, D); pools: (N, page, Hkv, D) already holding the new
    token at position ``lengths``; page_tables: (S, maxp) int32;
    lengths: (S,) int32.  Gathers each slot's full ``maxp*page`` KV
    window, masks positions past ``lengths`` and softmaxes — positions
    in padded table lanes point at the trash page but sit past the
    length, so they mask out identically to the kernel.  ``pin_logits``
    is a hook for the mesh path's sharding constraint (models/attention
    pins the KV-head axis to "model" there).  For compressed pools
    (``kv_format`` "int8"/"sc"), ``kv_aux`` carries the parallel
    ``k_scale``/``v_scale`` (N, page, Hkv) and — for sc — the
    ``k_resid``/``v_resid`` pools; dequant is fused into the gather.
    Returns (S, Hkv, G, D) in q.dtype.
    """
    S, Hkv, G, D = q.shape
    aux = kv_aux or {}
    kg = gather_pages_dequant(k_pages, page_tables, kv_format=kv_format,
                              scale=aux.get("k_scale"),
                              resid=aux.get("k_resid"))  # (S, T, Hkv, Dh)
    vg = gather_pages_dequant(v_pages, page_tables, kv_format=kv_format,
                              scale=aux.get("v_scale"),
                              resid=aux.get("v_resid"))
    T = kg.shape[1]
    logits = jnp.einsum("shgd,sthd->shgt", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) / math.sqrt(D)
    if pin_logits is not None:
        logits = pin_logits(logits)
    valid = (jnp.arange(T)[None, :] <= lengths[:, None])    # (S, T)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("shgt,sthd->shgd", w, vg.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_attn_verify_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, page_tables: jax.Array,
                          lengths: jax.Array, *, pin_logits=None,
                          kv_format: str = "fp",
                          kv_aux: dict | None = None) -> jax.Array:
    """Parallel multi-token verify attention over the paged cache.

    The speculative-decoding verify step: lane ``s`` scores ``Tq``
    queries at consecutive positions ``lengths[s] + t`` (t = 0..Tq-1) in
    ONE pass, each under its own causal horizon — query t attends keys
    at positions ``<= lengths[s] + t``.  q: (S, Tq, Hkv, G, D); pools
    already hold the verify window's K/V scatter at those positions.
    Masked positions past each query's horizon softmax to exact 0, so
    row t is arithmetically the decode-ref row at length ``lengths+t``
    — the differential tests pin token identity with plain decode.
    Returns (S, Tq, Hkv, G, D) in q.dtype.
    """
    S, Tq, Hkv, G, D = q.shape
    aux = kv_aux or {}
    kg = gather_pages_dequant(k_pages, page_tables, kv_format=kv_format,
                              scale=aux.get("k_scale"),
                              resid=aux.get("k_resid"))  # (S, T, Hkv, Dh)
    vg = gather_pages_dequant(v_pages, page_tables, kv_format=kv_format,
                              scale=aux.get("v_scale"),
                              resid=aux.get("v_resid"))
    T = kg.shape[1]
    logits = jnp.einsum("sqhgd,sthd->shgqt", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) / math.sqrt(D)
    if pin_logits is not None:
        logits = pin_logits(logits)
    horizon = lengths[:, None] + jnp.arange(Tq)[None, :]     # (S, Tq)
    valid = (jnp.arange(T)[None, None, :] <= horizon[:, :, None])
    logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("shgqt,sthd->sqhgd", w, vg.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_attn_prefill_ref(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_tables: jax.Array,
                           start: int, *, pin_logits=None,
                           kv_format: str = "fp",
                           kv_aux: dict | None = None) -> jax.Array:
    """XLA gather paged prefill — chunk ``[start, start+C)`` attends over
    every page written so far under the causal mask.

    q: (G, C, Hkv, Gq, D); pools: (N, page, Hkv, D) already holding the
    chunk's whole-page K/V scatter; page_tables: (G, maxp).  Compressed
    pools dequantize inside the gather via ``kv_aux`` exactly as in
    :func:`paged_attn_decode_ref`.  Returns (G, C, Hkv, Gq, D) in
    q.dtype.
    """
    G, C, Hkv, Gq, D = q.shape
    page = k_pages.shape[1]
    seen = page_tables[:, :(start + C) // page]   # pages <= this chunk
    aux = kv_aux or {}
    kg = gather_pages_dequant(k_pages, seen, kv_format=kv_format,
                              scale=aux.get("k_scale"),
                              resid=aux.get("k_resid"))  # (G, T, Hkv, Dh)
    vg = gather_pages_dequant(v_pages, seen, kv_format=kv_format,
                              scale=aux.get("v_scale"),
                              resid=aux.get("v_resid"))
    T = kg.shape[1]
    logits = jnp.einsum("sqhgd,sthd->shgqt", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) / math.sqrt(D)
    if pin_logits is not None:
        logits = pin_logits(logits)
    causal = (jnp.arange(T)[None, :] <=
              (start + jnp.arange(C))[:, None])   # (C, T)
    logits = jnp.where(causal[None, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("shgqt,sthd->sqhgd", w, vg.astype(jnp.float32))
    return o.astype(q.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Plain softmax attention oracle with GQA broadcast.

    q: (B,S,Hq,D); k,v: (B,S,Hkv,D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, S, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                        k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)

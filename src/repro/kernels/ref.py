"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

``ternary_matmul_ref`` is additionally proven equivalent to the bit-exact
multiplier+BSN circuit simulation in tests/test_hwmodel_sc_layers.py, so
the chain  Pallas kernel == this oracle == the silicon datapath  is closed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ternary_matmul_ref", "bsn_sort_ref", "si_epilogue_ref"]


def si_epilogue_ref(sum_q: jax.Array, thresholds_q: jax.Array) -> jax.Array:
    """SI activation on accumulated sums (q domain).

    thresholds_q: (N, out_bsl) int32, ascending along the last axis.
    out_q = #{j : sum_q >= t_j} - out_bsl/2.
    """
    t = thresholds_q.astype(jnp.int32)
    out_counts = jnp.sum(sum_q[..., None] >= t, axis=-1, dtype=jnp.int32)
    return out_counts - t.shape[-1] // 2


def ternary_matmul_ref(x_q: jax.Array, w_int: jax.Array,
                       thresholds_q: jax.Array | None = None) -> jax.Array:
    """int8 activation levels x int8 ternary weights -> int32 sums.

    Functional identity with the SC datapath: the int32 accumulate equals
    the BSN's sorted popcount (minus the fixed offset), and the optional
    epilogue is the SI wiring.
    """
    sum_q = jax.lax.dot_general(
        x_q.astype(jnp.int32), w_int.astype(jnp.int32),
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    if thresholds_q is None:
        return sum_q
    return si_epilogue_ref(sum_q, thresholds_q)


def bsn_sort_ref(bits: jax.Array) -> jax.Array:
    """Descending sort of the trailing axis (thermometer normal form)."""
    return jnp.sort(bits, axis=-1)[..., ::-1]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Plain softmax attention oracle with GQA broadcast.

    q: (B,S,Hq,D); k,v: (B,S,Hkv,D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, S, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                        k.astype(jnp.float32)) / jnp.sqrt(float(D))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)

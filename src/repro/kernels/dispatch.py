"""Kernel dispatch: pick pallas / pallas-interpret / reference per call.

Single policy point for how the approximate-BSN adder AND the paged
attention execute:

* ``"pallas"``            — compiled Mosaic kernel (real TPU).
* ``"pallas-interpret"``  — same kernel through the Pallas interpreter;
  bit-for-bit the compiled semantics, runs anywhere.  This is what the
  differential tests and this CPU container use.
* ``"reference"``         — the pure-JAX oracle (core/bsn.py counts for
  the BSN; the XLA gather/scatter paged attention in kernels/ref.py —
  also the right answer for tiny shapes where a pallas_call is all
  overhead).

Resolution order for every call: explicit ``backend=`` argument, then an
active scope / process default (:func:`backend_scope` for the BSN,
:func:`attn_backend_scope` for paged attention — separate knobs because
an engine may want the BSN circuit pinned while attention autotunes),
then auto (TPU + kernel-worthy row count -> ``pallas``; kernel-worthy
row count elsewhere -> ``pallas-interpret``; otherwise ``reference``).
The decision happens at Python trace time, so a scope must wrap the
*first* (tracing) call of a jitted function — ServeEngine does exactly
that.

``core.bsn.approx_bsn`` forwards here lazily, so library users reach the
kernel without importing repro.kernels themselves.
"""

from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core.bsn import (ApproxBSNSpec, approx_bsn_counts,
                            default_approx_spec, spatial_temporal_counts)

from . import ref
from .approx_bsn import (approx_bsn_pallas, approx_bsn_plan,
                         approx_bsn_temporal_pallas,
                         approx_bsn_temporal_plan)
from .paged_attention import (paged_attn_decode_pallas,
                              paged_attn_decode_plan,
                              paged_attn_prefill_pallas,
                              paged_attn_prefill_plan)

__all__ = ["BACKENDS", "select_backend", "set_default_backend",
           "get_default_backend", "backend_scope", "approx_bsn",
           "spec_stages", "attn_backend_scope", "set_attn_backend",
           "get_attn_backend", "paged_attn_decode", "paged_attn_prefill",
           "KernelEntry", "KERNEL_REGISTRY"]

BACKENDS = ("pallas", "pallas-interpret", "reference")

_default_backend: str | None = None


def set_default_backend(backend: str | None) -> None:
    """Process-wide override; ``None`` restores auto selection."""
    global _default_backend
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, want one of "
                         f"{BACKENDS} or None")
    _default_backend = backend


def get_default_backend() -> str | None:
    return _default_backend


@contextlib.contextmanager
def backend_scope(backend: str | None) -> Iterator[None]:
    """Temporarily pin the dispatch backend (``None`` scopes are no-ops
    rather than resets, so nested engines compose)."""
    if backend is None:
        yield
        return
    prev = _default_backend
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(prev)


def select_backend(rows: int, *, backend: str | None = None,
                   min_rows_for_kernel: int = 8,
                   default: str | None = None) -> str:
    """Resolve the backend for a call over ``rows`` independent codes.

    The row threshold applies on EVERY auto-selected backend: below it a
    pallas_call is all overhead (and ``rows == 0`` is a degenerate grid),
    so tiny shapes take the reference even on TPU.  ``default`` lets a
    subsystem supply its own scope value (attention passes the attn
    scope; the BSN path passes nothing and uses the module default).
    """
    if backend is None:
        backend = _default_backend if default is None else default
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        return backend
    if rows < min_rows_for_kernel:
        return "reference"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "pallas-interpret"


def spec_stages(spec: ApproxBSNSpec) -> tuple[tuple[int, int, int], ...]:
    """ApproxBSNSpec -> the primitive static tuples the kernel takes."""
    return tuple((s.group, s.sub.clip, s.sub.stride) for s in spec.stages)


def approx_bsn(counts: jax.Array, spec: ApproxBSNSpec, *, cycles: int = 1,
               backend: str | None = None, block_r: int = 256,
               min_rows_for_kernel: int = 8) -> jax.Array:
    """Approximate-BSN accumulation of ``(..., cycles*width)`` popcounts.

    Returns the output-code popcounts ``(...,)``; represented value is
    ``spec.scale * (out - cycles * spec.out_bsl // 2)``.  Any leading
    batch shape; rows are flattened, padded to ``block_r`` and cropped.
    """
    total = cycles * spec.width
    if counts.shape[-1] != total:
        raise ValueError(f"expected trailing dim {total} "
                         f"(cycles={cycles} x width={spec.width}), "
                         f"got {counts.shape}")
    batch = counts.shape[:-1]
    # static-shape host math: math.prod, not np.prod — this function is
    # reachable from traced code and the host-op lint keeps np out of it
    rows = math.prod(batch) if batch else 1
    chosen = select_backend(rows, backend=backend,
                            min_rows_for_kernel=min_rows_for_kernel)
    if rows == 0:
        # zero-size leading batch dim: a pallas_call over 0 rows is a
        # degenerate grid — the reference returns the empty result with
        # the right trailing shape/dtype regardless of requested backend
        chosen = "reference"

    if chosen == "reference":
        if cycles == 1:
            return approx_bsn_counts(counts, spec)
        return spatial_temporal_counts(counts, spec, cycles)

    interpret = chosen == "pallas-interpret"
    block_r = min(block_r, max(8, 1 << (rows - 1).bit_length()))
    rp = (rows + block_r - 1) // block_r * block_r
    x2 = counts.reshape(rows, total).astype(jnp.int32)
    x2 = jnp.pad(x2, ((0, rp - rows), (0, 0)))
    kw = dict(in_bsl=spec.in_bsl, stages=spec_stages(spec),
              block_r=block_r, interpret=interpret)
    if cycles == 1:
        out = approx_bsn_pallas(x2, **kw)
    else:
        out = approx_bsn_temporal_pallas(x2, cycles=cycles, **kw)
    out = out[:rows]
    return out.reshape(batch) if batch else out[0]


# ---------------------------------------------------------------------------
# paged attention (serving decode / prefill hot path)
# ---------------------------------------------------------------------------

_attn_backend: str | None = None


def set_attn_backend(backend: str | None) -> None:
    """Process-wide paged-attention override; ``None`` restores auto."""
    global _attn_backend
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, want one of "
                         f"{BACKENDS} or None")
    _attn_backend = backend


def get_attn_backend() -> str | None:
    return _attn_backend


@contextlib.contextmanager
def attn_backend_scope(backend: str | None) -> Iterator[None]:
    """Pin the paged-attention backend for traced calls (``None`` scopes
    are no-ops rather than resets, so nested engines compose).  Like
    :func:`backend_scope` this must wrap the first (tracing) call —
    ``ServeEngine(attn_backend=...)`` does."""
    if backend is None:
        yield
        return
    prev = _attn_backend
    set_attn_backend(backend)
    try:
        yield
    finally:
        set_attn_backend(prev)


def paged_attn_decode(q: jax.Array, k_pages: jax.Array,
                      v_pages: jax.Array, page_tables: jax.Array,
                      lengths: jax.Array, *, backend: str | None = None,
                      num_splits: int = 1,
                      min_rows_for_kernel: int = 8,
                      kv_format: str = "fp",
                      kv_aux: dict | None = None) -> jax.Array:
    """Batched one-token paged decode: (S, Hkv, G, D) queries against the
    (N, page, Hkv, D) pools through (S, maxp) tables, masked by
    ``lengths``.  Flash-decoding Pallas kernel on the kernel backends,
    XLA gather oracle (kernels/ref.py) on ``"reference"``.  Compressed
    pools (``kv_format`` "int8"/"sc") pass the parallel scale/residual
    pools in ``kv_aux`` (keys ``k_scale``/``v_scale``[/``k_resid``/
    ``v_resid``]); both backends fuse the dequant into the page reads."""
    S, Hkv, G, _ = q.shape
    aux = kv_aux or {}
    chosen = select_backend(S * Hkv * G, backend=backend,
                            min_rows_for_kernel=min_rows_for_kernel,
                            default=_attn_backend)
    if chosen == "reference":
        return ref.paged_attn_decode_ref(q, k_pages, v_pages,
                                         page_tables, lengths,
                                         kv_format=kv_format, kv_aux=aux)
    return paged_attn_decode_pallas(q, k_pages, v_pages, page_tables,
                                    lengths, num_splits=num_splits,
                                    interpret=chosen == "pallas-interpret",
                                    kv_format=kv_format, **aux)


def paged_attn_prefill(q: jax.Array, k_pages: jax.Array,
                       v_pages: jax.Array, page_tables: jax.Array,
                       start: int, *, backend: str | None = None,
                       block_q: int = 32,
                       min_rows_for_kernel: int = 8,
                       kv_format: str = "fp",
                       kv_aux: dict | None = None) -> jax.Array:
    """One chunk of paged prefill: (G, C, Hkv, Gq, D) queries at
    positions ``[start, start+C)`` against every page written so far,
    causal.  Same backend chain (and ``kv_format``/``kv_aux`` contract)
    as :func:`paged_attn_decode`."""
    G, C, Hkv, Gq, _ = q.shape
    aux = kv_aux or {}
    chosen = select_backend(G * C * Hkv * Gq, backend=backend,
                            min_rows_for_kernel=min_rows_for_kernel,
                            default=_attn_backend)
    if chosen == "reference":
        return ref.paged_attn_prefill_ref(q, k_pages, v_pages,
                                          page_tables, start,
                                          kv_format=kv_format, kv_aux=aux)
    return paged_attn_prefill_pallas(q, k_pages, v_pages, page_tables,
                                     start=start, block_q=block_q,
                                     interpret=chosen == "pallas-interpret",
                                     kv_format=kv_format, **aux)


# ---------------------------------------------------------------------------
# kernel registry: static-audit metadata for every dispatched kernel
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelEntry:
    """One dispatched Pallas kernel, as the static auditor sees it.

    ``build_plan`` is the kernel's pure-Python launch-plan builder (the
    same one the executing wrapper calls — audited geometry cannot drift
    from executed geometry).  ``kv_formats`` lists the compressed-pool
    variants the kernel compiles per format (empty when kv_format does
    not apply, e.g. the BSN adder).  ``audit_cases()`` returns
    ``(label, plan_kwargs)`` pairs covering the autotune sweep shapes,
    so the auditor can prune/verify exactly the configs the autotuner
    would compile.  New kernels MUST register here before dispatch:
    ``tests/test_kernel_audit.py`` audits every entry x format.
    """
    name: str
    build_plan: Callable
    kv_formats: tuple[str, ...]
    audit_cases: Callable[[], tuple[tuple[str, dict], ...]]


def _bsn_case(rows: int, width: int, block_r: int,
              cycles: int = 1) -> tuple[str, dict]:
    """Mirror dispatch.approx_bsn's clamp-then-pad of (rows, block_r)."""
    br = min(block_r, max(8, 1 << (rows - 1).bit_length()))
    rp = (rows + br - 1) // br * br
    spec = default_approx_spec(width, 2)
    kw = dict(rows=rp, width=width, in_bsl=spec.in_bsl,
              stages=spec_stages(spec), block_r=br)
    if cycles > 1:
        kw["cycles"] = cycles
    return f"r{rows}_w{width}_b{br}" + (f"_t{cycles}" if cycles > 1
                                        else ""), kw


def _bsn_spatial_cases() -> tuple[tuple[str, dict], ...]:
    # the bench_approx_bsn autotune sweep: (rows, width) x block_r
    cases = {}
    for rows, width in ((64, 128), (64, 512), (256, 1152)):
        for block_r in (64, 128, 256):
            label, kw = _bsn_case(rows, width, block_r)
            cases[label] = kw                        # dedupe clamped ties
    return tuple(cases.items())


def _bsn_temporal_cases() -> tuple[tuple[str, dict], ...]:
    cases = {}
    for rows, width, cycles in ((64, 128, 4), (256, 128, 8)):
        for block_r in (64, 256):
            label, kw = _bsn_case(rows, width, block_r, cycles)
            cases[label] = kw
    return tuple(cases.items())


def _decode_cases() -> tuple[tuple[str, dict], ...]:
    # the bench_serving autotune shapes: the serving-scale decode point
    # and the longer-context split-K point (pools sized like
    # autotune._paged_case: S * maxp pages + the reserved trash page)
    cases = []
    for tag, maxp, splits in (("serving", 4, (1, 2, 4)),
                              ("long", 16, (1, 2, 4, 8))):
        for s in splits:
            if s > maxp:
                continue
            cases.append((f"{tag}_maxp{maxp}_splits{s}",
                          dict(S=8, Hkv=2, G=2, D=16, page=16, maxp=maxp,
                               num_pages=8 * maxp + 1, num_splits=s)))
    return tuple(cases)


def _prefill_cases() -> tuple[tuple[str, dict], ...]:
    return tuple(
        (f"chunk32_start32_bq{bq}",
         dict(G=4, C=32, Hkv=2, Gq=2, D=16, page=16, start=32,
              num_pages=4 * 4 + 1, table_width=4, block_q=bq))
        for bq in (8, 16, 32))


KERNEL_REGISTRY: dict[str, KernelEntry] = {
    e.name: e for e in (
        KernelEntry("approx_bsn_spatial", approx_bsn_plan, (),
                    _bsn_spatial_cases),
        KernelEntry("approx_bsn_temporal", approx_bsn_temporal_plan, (),
                    _bsn_temporal_cases),
        KernelEntry("paged_attn_decode", paged_attn_decode_plan,
                    ("fp", "int8", "sc"), _decode_cases),
        KernelEntry("paged_attn_prefill", paged_attn_prefill_plan,
                    ("fp", "int8", "sc"), _prefill_cases),
    )
}

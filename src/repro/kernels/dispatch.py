"""Kernel dispatch: pick pallas / pallas-interpret / reference per call.

Single policy point for how the approximate-BSN adder executes:

* ``"pallas"``            — compiled Mosaic kernel (real TPU).
* ``"pallas-interpret"``  — same kernel through the Pallas interpreter;
  bit-for-bit the compiled semantics, runs anywhere.  This is what the
  differential tests and this CPU container use.
* ``"reference"``         — the pure-JAX count oracle in core/bsn.py
  (also the right answer for tiny shapes where a pallas_call is all
  overhead).

Resolution order for every call: explicit ``backend=`` argument, then an
active :func:`backend_scope` / :func:`set_default_backend` override, then
auto (TPU -> ``pallas``; kernel-worthy row count elsewhere ->
``pallas-interpret``; otherwise ``reference``).  The decision happens at
Python trace time, so a scope must wrap the *first* (tracing) call of a
jitted function — ServeEngine does exactly that.

``core.bsn.approx_bsn`` forwards here lazily, so library users reach the
kernel without importing repro.kernels themselves.
"""

from __future__ import annotations

import contextlib
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsn import (ApproxBSNSpec, approx_bsn_counts,
                            spatial_temporal_counts)

from .approx_bsn import approx_bsn_pallas, approx_bsn_temporal_pallas

__all__ = ["BACKENDS", "select_backend", "set_default_backend",
           "get_default_backend", "backend_scope", "approx_bsn",
           "spec_stages"]

BACKENDS = ("pallas", "pallas-interpret", "reference")

_default_backend: str | None = None


def set_default_backend(backend: str | None) -> None:
    """Process-wide override; ``None`` restores auto selection."""
    global _default_backend
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, want one of "
                         f"{BACKENDS} or None")
    _default_backend = backend


def get_default_backend() -> str | None:
    return _default_backend


@contextlib.contextmanager
def backend_scope(backend: str | None) -> Iterator[None]:
    """Temporarily pin the dispatch backend (``None`` scopes are no-ops
    rather than resets, so nested engines compose)."""
    if backend is None:
        yield
        return
    prev = _default_backend
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(prev)


def select_backend(rows: int, *, backend: str | None = None,
                   min_rows_for_kernel: int = 8) -> str:
    """Resolve the backend for a call over ``rows`` independent codes."""
    if backend is None:
        backend = _default_backend
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        return backend
    if jax.default_backend() == "tpu":
        return "pallas"
    if rows >= min_rows_for_kernel:
        return "pallas-interpret"
    return "reference"


def spec_stages(spec: ApproxBSNSpec) -> tuple[tuple[int, int, int], ...]:
    """ApproxBSNSpec -> the primitive static tuples the kernel takes."""
    return tuple((s.group, s.sub.clip, s.sub.stride) for s in spec.stages)


def approx_bsn(counts: jax.Array, spec: ApproxBSNSpec, *, cycles: int = 1,
               backend: str | None = None, block_r: int = 256,
               min_rows_for_kernel: int = 8) -> jax.Array:
    """Approximate-BSN accumulation of ``(..., cycles*width)`` popcounts.

    Returns the output-code popcounts ``(...,)``; represented value is
    ``spec.scale * (out - cycles * spec.out_bsl // 2)``.  Any leading
    batch shape; rows are flattened, padded to ``block_r`` and cropped.
    """
    total = cycles * spec.width
    if counts.shape[-1] != total:
        raise ValueError(f"expected trailing dim {total} "
                         f"(cycles={cycles} x width={spec.width}), "
                         f"got {counts.shape}")
    batch = counts.shape[:-1]
    rows = int(np.prod(batch)) if batch else 1
    chosen = select_backend(rows, backend=backend,
                            min_rows_for_kernel=min_rows_for_kernel)

    if chosen == "reference":
        if cycles == 1:
            return approx_bsn_counts(counts, spec)
        return spatial_temporal_counts(counts, spec, cycles)

    interpret = chosen == "pallas-interpret"
    block_r = min(block_r, max(8, 1 << (rows - 1).bit_length()))
    rp = (rows + block_r - 1) // block_r * block_r
    x2 = counts.reshape(rows, total).astype(jnp.int32)
    x2 = jnp.pad(x2, ((0, rp - rows), (0, 0)))
    kw = dict(in_bsl=spec.in_bsl, stages=spec_stages(spec),
              block_r=block_r, interpret=interpret)
    if cycles == 1:
        out = approx_bsn_pallas(x2, **kw)
    else:
        out = approx_bsn_temporal_pallas(x2, cycles=cycles, **kw)
    out = out[:rows]
    return out.reshape(batch) if batch else out[0]

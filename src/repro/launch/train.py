"""Training launcher: end-to-end driver over the full substrate.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduce 8 --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/run1

On this CPU container you train *reduced* configs (--reduce divides
widths/layers); on a real TPU fleet the same entrypoint runs the full
config over the production mesh (--mesh single|multi) with the identical
code path: pjit'd train_step, sharded AdamW, async checkpoints,
SIGTERM-safe preemption, stateless data resume.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.data import SyntheticLM
from repro.distributed.sharding import (MeshRules, mesh_rules,
                                        multipod_mapping)
from repro.models import init_params, make_dummy_batch
from repro.optim import warmup_cosine
from repro.train import build_train_step, init_train_state, run_training


def reduced_config(cfg, factor: int, seq: int):
    if factor <= 1:
        return cfg
    period = len(cfg.period)
    layers = max(period, (cfg.n_layers // factor) // period * period)
    d_model = max(64, cfg.d_model // factor // 64 * 64)
    heads = max(4, cfg.n_heads // factor)
    kv = max(2, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return cfg.scaled(
        n_layers=layers, d_model=d_model, n_heads=heads, n_kv_heads=kv,
        d_ff=max(128, cfg.d_ff // factor // 32 * 32),
        vocab_size=min(cfg.vocab_size, 2048),
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        n_experts_per_tok=min(cfg.n_experts_per_tok, 2)
        if cfg.n_experts else 0,
        vocab_pad_multiple=64, dtype="float32",
        attn_q_chunk=min(cfg.attn_q_chunk, max(seq // 2, 16)),
        moe_group_size=64, d_head=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduce", type=int, default=8,
                    help="width/depth reduction factor (1 = full config)")
    ap.add_argument("--quant", choices=["none", "sc_qat"], default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    cfg = reduced_config(cfg, args.reduce, args.seq)
    if args.quant:
        cfg = cfg.with_quant(args.quant) if args.quant != "none" \
            else cfg.scaled(quant=cfg.quant.with_mode("none"))
    print(f"[train] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"quant={cfg.quant.mode} params on {len(jax.devices())} device(s)")

    params = init_params(jax.random.key(args.seed), cfg)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {n/1e6:.1f}M parameters")
    state = init_train_state(params, cfg, grad_compress=args.grad_compress)

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     seed=args.seed)

    def batch_fn(step):
        b = ds.batch(step, args.batch)
        tgt = jnp.clip(b["targets"], 0, cfg.vocab_size - 1)
        if cfg.frontend == "vision_stub":
            # stubbed frontend: random-but-deterministic patch embeddings,
            # loss only on the text suffix
            d = make_dummy_batch(cfg, args.batch, args.seq, "train")
            n_img = d["patch_embeds"].shape[1]
            key = jax.random.fold_in(jax.random.key(7), step)
            d["patch_embeds"] = 0.02 * jax.random.normal(
                key, d["patch_embeds"].shape, jnp.float32)
            d["tokens"] = b["tokens"][:, :args.seq - n_img]
            d["targets"] = tgt
            d["loss_mask"] = jnp.concatenate(
                [jnp.zeros((args.batch, n_img), jnp.float32),
                 jnp.ones((args.batch, args.seq - n_img), jnp.float32)], 1)
            return d
        if cfg.frontend == "audio_stub":
            d = make_dummy_batch(cfg, args.batch, args.seq, "train")
            key = jax.random.fold_in(jax.random.key(8), step)
            d["frames"] = 0.1 * jax.random.normal(key, d["frames"].shape,
                                                  jnp.float32)
            d["targets"] = tgt
            return d
        return dict(b, targets=tgt)

    step_fn = jax.jit(build_train_step(
        cfg, lambda s: warmup_cosine(s, args.lr, 10, args.steps),
        grad_accum=args.grad_accum, grad_compress=args.grad_compress),
        donate_argnums=0)

    state, history = run_training(
        step_fn, state, batch_fn, args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=max(args.steps // 20, 1))
    if history:
        print(f"[train] done: loss {history[0]['loss']:.4f} -> "
              f"{history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

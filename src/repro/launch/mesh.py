"""Production mesh construction (DESIGN.md §5).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
the dry-run sees 512 forced host devices).
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_serving_mesh", "serving_rules",
           "mesh_chips", "mesh_name"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model
    across 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    return jax.sharding.Mesh(_device_grid(devices[:n], shape), axes)


def make_serving_mesh(model_parallel: int | None = None,
                      data_parallel: int = 1):
    """(data, model) mesh for the tensor-parallel serving engine.

    ``model_parallel`` defaults to every visible device after
    ``data_parallel`` is carved off.  Works on any device count (tests
    force host devices via XLA_FLAGS=--xla_force_host_platform_device_
    count=8); a single device yields a degenerate (1, 1) mesh, which the
    engine treats identically to no mesh at all.
    """
    devices = jax.devices()
    if model_parallel is None:
        model_parallel = max(1, len(devices) // data_parallel)
    need = data_parallel * model_parallel
    if len(devices) < need:
        raise RuntimeError(
            f"serving mesh ({data_parallel}, {model_parallel}) needs "
            f"{need} devices, found {len(devices)}")
    return jax.sharding.Mesh(
        _device_grid(devices[:need], (data_parallel, model_parallel)),
        ("data", "model"))


def serving_rules(mesh):
    """MeshRules with the serving logical mapping (weights resident over
    "model", no fsdp/seq axes) — what ServeEngine(mesh_rules=...) wants."""
    from repro.distributed.sharding import MeshRules, serving_mapping
    return MeshRules(mesh=mesh, mapping=serving_mapping())


def _device_grid(devices, shape):
    import numpy as np
    return np.asarray(devices, dtype=object).reshape(shape)


def mesh_chips(mesh) -> int:
    return mesh.devices.size


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)

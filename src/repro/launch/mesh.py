"""Production mesh construction (DESIGN.md §5).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
the dry-run sees 512 forced host devices).
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "mesh_chips", "mesh_name"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model
    across 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    return jax.sharding.Mesh(_device_grid(devices[:n], shape), axes)


def _device_grid(devices, shape):
    import numpy as np
    return np.asarray(devices, dtype=object).reshape(shape)


def mesh_chips(mesh) -> int:
    return mesh.devices.size


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell on 512 forced host devices,
record memory_analysis / cost_analysis / roofline terms.

One cell:   python -m repro.launch.dryrun --arch granite-3-2b \
                --shape train_4k --mesh both
All cells:  python -m repro.launch.dryrun --all   (subprocess per cell so a
            pathological compile can't take the sweep down — straggler
            containment for the sweep itself)

Skip rules (DESIGN.md §4): encoder archs skip decode shapes; pure
full-attention archs skip long_500k. Skips are *recorded* in the report.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.roofline import roofline_from_compiled
from repro.configs import SHAPES, get_arch, list_archs, shape_by_name
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (MeshRules, mesh_rules,
                                        multipod_mapping)
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_name
from repro.models import (batch_specs, cache_specs, decode_step, init_cache,
                          init_params, loss_fn, param_specs, prefill)
from repro.optim import opt_state_specs
from repro.train import build_train_step, init_train_state

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")

VLM_IMG_TOKENS = 2880


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "pure full-attention arch: 524k context needs sub-quadratic attention"
    return None


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in list_archs() for s in SHAPES]


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders
# ---------------------------------------------------------------------------

def _sds(tree, spec_tree, rules: MeshRules, logical: bool):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(sd, spec):
        if sd is None:                     # e.g. TrainState.error unused
            return None
        if spec is None:
            spec = P()
        if logical:
            spec = rules.resolve(spec)
        return jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(rules.mesh, spec))

    is_leaf = (lambda s: s is None or isinstance(s, (tuple,))) if logical \
        else (lambda s: s is None or isinstance(
            s, jax.sharding.PartitionSpec))
    return jax.tree.map(one, tree, spec_tree, is_leaf=is_leaf)


def _batch_sds(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules,
               kind: str):
    b, s = shape.global_batch, shape.seq_len
    fields = {}
    if cfg.frontend == "vision_stub":
        img = min(VLM_IMG_TOKENS, s // 2)
        fields["patch_embeds"] = jax.ShapeDtypeStruct((b, img, 1024),
                                                      jnp.bfloat16)
        fields["tokens"] = jax.ShapeDtypeStruct((b, s - img), jnp.int32)
    elif cfg.frontend == "audio_stub":
        fields["frames"] = jax.ShapeDtypeStruct((b, s, 512), jnp.bfloat16)
    else:
        fields["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if kind == "train":
        fields["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        fields["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    specs = {k: batch_specs(cfg, kind).get(k, ("batch", None))
             for k in fields}
    return _sds(fields, specs, rules, logical=True)


def _params_sds(cfg: ModelConfig, rules: MeshRules, serving: bool = False):
    shapes = jax.eval_shape(partial(init_params, cfg=cfg), jax.random.key(0))
    return _sds(shapes, param_specs(cfg, serving=serving), rules,
                logical=False)


# ---------------------------------------------------------------------------
# lowering per cell kind
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules):
    from jax.sharding import PartitionSpec as P
    mesh = rules.mesh
    p_sds = _params_sds(cfg, rules, serving=(shape.kind == "decode"))

    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda p: init_train_state(p, cfg), p_sds)
        pspecs = param_specs(cfg)
        from repro.train.step import TrainState
        state_specs = TrainState(params=pspecs,
                                 opt=opt_state_specs(pspecs),
                                 step=P(), error=None)
        state_sds = _sds(state_shapes, state_specs, rules, logical=False)
        batch = _batch_sds(cfg, shape, rules, "train")
        step_fn = build_train_step(cfg, lambda s: 3e-4)
        jitted = jax.jit(step_fn, donate_argnums=0)
        return jitted.lower(state_sds, batch)

    if shape.kind == "prefill":
        batch = _batch_sds(cfg, shape, rules, "prefill")
        fn = jax.jit(lambda p, b: prefill(p, b, cfg))
        if cfg.is_encoder:
            from repro.models import forward
            fn = jax.jit(lambda p, b: forward(p, b, cfg, mode="train")[0])
        return fn.lower(p_sds, batch)

    # decode
    b, s = shape.global_batch, shape.seq_len
    seq_shard = shape.name == "long_500k"
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    kv_head_shard = (cfg.n_kv_heads % model_size == 0) and not seq_shard
    cache_shapes = jax.eval_shape(
        partial(init_cache, cfg, b, s))
    cspecs = cache_specs(cfg, seq_shard=seq_shard,
                         kv_head_shard=kv_head_shard)
    cache_sds = _sds(cache_shapes, cspecs, rules, logical=True)
    tok = _sds(jax.ShapeDtypeStruct((b, 1), jnp.int32), ("batch", None),
               rules, logical=True)
    fn = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg),
                 donate_argnums=1)
    return fn.lower(p_sds, cache_sds, tok)


# ---------------------------------------------------------------------------
# one cell end-to-end
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant: str | None = None, report_dir: str = REPORT_DIR,
             verbose: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_arch(arch)
    if quant:
        cfg = cfg.with_quant(quant) if quant != "none" \
            else cfg.scaled(quant=cfg.quant.with_mode("none"))
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    record = {"arch": arch, "shape": shape_name, "mesh": mname,
              "chips": mesh_chips(mesh), "quant": cfg.quant.mode,
              "status": "?"}

    skip = cell_skip_reason(cfg, shape)
    if skip:
        record.update(status="skipped", reason=skip)
        _save(record, report_dir)
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name} x {mname}: {skip}")
        return record

    mapping = multipod_mapping()
    if shape.global_batch == 1:
        # long_500k: batch can't occupy a mesh axis; "seq" (data) carries
        # the context parallelism instead
        mapping = dict(mapping, batch=())
    rules = MeshRules(mesh=mesh, mapping=mapping)
    t0 = time.time()
    with mesh_rules(rules):
        lowered = lower_cell(cfg, shape, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        rep = roofline_from_compiled(compiled, cfg, shape, mname,
                                     mesh_chips(mesh))
    record.update(
        status="ok", lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory_analysis={
            "argument_size_in_bytes": ma.argument_size_in_bytes,
            "output_size_in_bytes": ma.output_size_in_bytes,
            "temp_size_in_bytes": ma.temp_size_in_bytes,
            "alias_size_in_bytes": ma.alias_size_in_bytes,
            "peak_memory_in_bytes": ma.peak_memory_in_bytes,
        },
        cost_analysis={k: v for k, v in
                       (compiled.cost_analysis() or {}).items()
                       if k in ("flops", "bytes accessed")},
        roofline=json.loads(rep.to_json()),
    )
    _save(record, report_dir)
    if verbose:
        gb = ma.peak_memory_in_bytes / 2 ** 30
        r = record["roofline"]
        print(f"[dryrun] OK {arch} x {shape_name} x {mname}: "
              f"peak/device {gb:.2f} GiB  "
              f"terms(c/m/coll)={r['t_compute']:.3e}/{r['t_memory']:.3e}/"
              f"{r['t_collective']:.3e}s  bottleneck={r['bottleneck']} "
              f"frac={r['roofline_fraction']:.2f} "
              f"(lower {record['lower_s']}s compile {record['compile_s']}s)")
    return record


def _save(record: dict, report_dir: str):
    os.makedirs(report_dir, exist_ok=True)
    fn = (f"{record['arch']}__{record['shape']}__{record['mesh']}"
          f"__{record.get('quant', 'q')}.json")
    with open(os.path.join(report_dir, fn), "w") as f:
        json.dump(record, f, indent=1)


# ---------------------------------------------------------------------------
# sweep orchestration (subprocess per cell)
# ---------------------------------------------------------------------------

def sweep(meshes: list[bool], quant: str | None, report_dir: str,
          only_missing: bool = False):
    results = []
    for arch, shape_name in all_cells():
        for multi in meshes:
            mname = "2x16x16" if multi else "16x16"
            out = os.path.join(
                report_dir, f"{arch}__{shape_name}__{mname}"
                f"__{quant or get_arch(arch).quant.mode}.json")
            if only_missing and os.path.exists(out):
                with open(out) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    results.append((arch, shape_name, mname, prev["status"]))
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--mesh", "multi" if multi else "single",
                   "--report-dir", report_dir]
            if quant:
                cmd += ["--quant", quant]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            status = "ok"
            if r.returncode != 0:
                status = "FAILED"
                fail = {"arch": arch, "shape": shape_name, "mesh": mname,
                        "quant": quant or "default", "status": "failed",
                        "stderr": r.stderr[-4000:]}
                _save(fail, report_dir)
            print(f"[sweep] {arch} x {shape_name} x {mname}: {status} "
                  f"({time.time() - t0:.0f}s)")
            sys.stdout.write(r.stdout[-2000:] if r.returncode == 0
                             else r.stderr[-2000:] + "\n")
            results.append((arch, shape_name, mname, status))
    bad = [r for r in results if r[3] == "FAILED"]
    print(f"[sweep] done: {len(results)} cells, {len(bad)} failed")
    for b in bad:
        print("  FAILED:", b)
    return 1 if bad else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--quant", choices=["none", "sc_qat"], default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides for perf iterations, "
                         "e.g. --set ce_chunks=8 --set attn_q_chunk=2048")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lstrip("-").isdigit():
            overrides[k] = int(v)
        elif v in ("True", "False"):
            overrides[k] = v == "True"
        else:
            overrides[k] = v

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        sys.exit(sweep(meshes, args.quant, args.report_dir,
                       args.only_missing))
    assert args.arch and args.shape, "--arch/--shape or --all"
    for multi in meshes:
        try:
            run_cell(args.arch, args.shape, multi, args.quant,
                     args.report_dir, overrides=overrides or None)
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()

"""Roofline analysis + static hot-path contract checks (see README.md)."""

from .contracts import (PassResult, Violation, audit_donation,
                        audit_dtype_purity, audit_engine_retrace,
                        audit_host_boundary, audit_sharding,
                        run_engine_contracts)
from .hlo_cost import HloCost, analyze_hlo, parse_computations
from .kernel_audit import (audit_bounds, audit_grid, audit_registry,
                           audit_revisit, audit_vmem, run_plan_audits)
from .lint import LintViolation, lint_repo, lint_sources
from .roofline import RooflineReport, V5E, roofline_from_compiled

__all__ = ["HloCost", "analyze_hlo", "parse_computations",
           "RooflineReport", "V5E", "roofline_from_compiled",
           "Violation", "PassResult", "audit_donation",
           "audit_dtype_purity", "audit_host_boundary", "audit_sharding",
           "audit_engine_retrace", "run_engine_contracts",
           "audit_bounds", "audit_vmem", "audit_revisit", "audit_grid",
           "run_plan_audits", "audit_registry",
           "LintViolation", "lint_repo", "lint_sources"]

"""Roofline analysis from compiled dry-run artifacts."""

from .hlo_cost import HloCost, analyze_hlo
from .roofline import RooflineReport, V5E, roofline_from_compiled

__all__ = ["HloCost", "analyze_hlo", "RooflineReport", "V5E",
           "roofline_from_compiled"]

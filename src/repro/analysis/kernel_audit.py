"""Static auditor for the Pallas kernel fleet: BlockSpec bounds proofs,
VMEM budgets, and revisit/race checks — no kernel is ever executed.

The PR-8 contract gate (``contracts.py``) audits lowered jaxpr/HLO but
stops at every ``pallas_call`` boundary: an out-of-bounds page-table
index map, a VMEM-blowing block knob or a silently-revisited output
block is invisible to it and only surfaces in differential tests after
it has corrupted tokens (or, worse, only on real TPU hardware where an
out-of-range DMA reads garbage instead of raising).  This module closes
that gap by auditing the :class:`repro.kernels.plan.LaunchPlan` each
kernel now builds — the *same* object the executing wrapper launches,
so the audited geometry cannot drift from the executed one.

Passes (each returns a :class:`~repro.analysis.contracts.PassResult`,
so ANALYSIS.json carries kernel cells with the same shape as the
contract cells):

``bounds``   enumerate every BlockSpec index map over the full grid
             with scalar-prefetch operands pinned to their worst-case
             value model (page-table entries at ``num_pages - 1`` and
             ``0``; lengths at ``max_len - 1`` and the ragged
             ``plen % page_size in {0, 1, page_size - 1}`` fills) and
             prove every block read/write lands inside its operand —
             including the scale/residual aux pools.  Index maps in
             this fleet are elementwise monotone in their scalar
             entries, so the extremes are a proof, not a sample.
``vmem``     per-program VMEM estimate (double-buffered input/output
             block tiles + scratch) against a configurable budget —
             reported per (kernel, shape, config) so the autotuner can
             prune infeasible configs before compiling them
             (``kernels/autotune.py`` does exactly that).
``revisit``  detect output blocks written from more than one grid step
             and require (a) the plan declares an accumulation
             discipline for them, (b) the kernel body actually guards a
             first write / finalize with ``pl.when``, and (c) no
             revisited grid axis is declared ``parallel`` in
             ``dimension_semantics`` (that would be a write race on
             TPU).  A stale declaration on a non-revisited output also
             fails — metadata must stay honest.
``grid``     index-map arity == grid rank + scalar-prefetch count for
             every operand, block rank/size vs operand shape, every
             scalar-prefetch operand actually referenced (by an index
             map, or declared ``kernel_only``), unique operand names.

``audit_registry`` drives all four over every kernel registered in
``kernels/dispatch.KERNEL_REGISTRY`` x its kv_formats x the autotune
sweep shapes; ``tools/analyze.py --gate`` emits the result as the
``kernel_audit`` section of ANALYSIS.json.
"""

from __future__ import annotations

import inspect
import itertools

import numpy as np

from repro.kernels.plan import (DEFAULT_VMEM_BUDGET, LaunchPlan,
                                estimate_vmem, kernel_source_fn)

from .contracts import PassResult, results_to_json

__all__ = ["audit_bounds", "audit_vmem", "audit_revisit", "audit_grid",
           "run_plan_audits", "audit_registry", "scalar_sets",
           "DEFAULT_VMEM_BUDGET"]

_MAX_REPORTED = 3          # violations reported per (pass, operand)


def _arity(fn) -> int | None:
    try:
        return fn.__code__.co_argcount
    except AttributeError:
        try:
            return len(inspect.signature(fn).parameters)
        except (TypeError, ValueError):
            return None


def _map_provenance(fn) -> str:
    """``file.py:line`` of an index map, relative to the repro package —
    the same attribution style the contract passes use."""
    try:
        code = fn.__code__
        f = str(code.co_filename).replace("\\", "/")
        if "/repro/" in f:
            f = f.split("/repro/")[-1]
        return f"{f}:{code.co_firstlineno}"
    except AttributeError:
        return "<unknown>"


def _scalar_fills(sc) -> tuple[int, ...]:
    vals = {0, sc.max_value}
    vals.update(v for v in sc.values if 0 <= v <= sc.max_value)
    return tuple(sorted(vals))


def scalar_sets(plan: LaunchPlan) -> list[dict]:
    """Worst-case scalar-prefetch assignments: the cartesian product of
    each scalar operand's fill values, every array filled uniformly.
    Uniform extremes suffice because every index map in the fleet is
    elementwise monotone in the scalar entries it reads (a table entry
    feeds through unchanged as a block index) — see analysis/README.md
    for the model's contract."""
    if not plan.scalars:
        return [{}]
    names = [s.name for s in plan.scalars]
    grids = [_scalar_fills(s) for s in plan.scalars]
    out = []
    for fills in itertools.product(*grids):
        out.append({n: np.full(s.shape, v, dtype=np.dtype("int32"))
                    for n, s, v in zip(names, plan.scalars, fills)})
    return out


def _grid_points(plan: LaunchPlan):
    return itertools.product(*(range(g) for g in plan.grid))


def _bad_arity_ops(plan: LaunchPlan) -> set[str]:
    want = len(plan.grid) + len(plan.scalars)
    return {op.name for op in plan.inputs + plan.outputs
            if _arity(op.index_map) not in (None, want)}


def _scalar_args(plan: LaunchPlan, arrs: dict) -> list:
    return [arrs[s.name] for s in plan.scalars]


# ---------------------------------------------------------------------------
# pass 1: bounds
# ---------------------------------------------------------------------------

def audit_bounds(label: str, plan: LaunchPlan) -> PassResult:
    """Prove every block index lands inside its operand for the whole
    grid under every worst-case scalar set.  A block index ``i`` on a
    dim of extent ``n`` with block ``b`` is legal iff
    ``0 <= i < ceil(n / b)`` (Pallas pads a partial final block; past
    that is an out-of-bounds DMA that corrupts silently on TPU)."""
    res = PassResult("bounds", label)
    skip = _bad_arity_ops(plan)
    if skip:
        res.notes.append(f"operands skipped for index-map arity mismatch "
                         f"(see grid pass): {sorted(skip)}")
    sets = scalar_sets(plan)
    checked = 0
    reported: dict[str, int] = {}
    for op in plan.inputs + plan.outputs:
        if op.name in skip:
            continue
        nblocks = tuple(-(-s // b) for s, b in zip(op.shape, op.block))
        prov = _map_provenance(op.index_map)
        for arrs in sets:
            sargs = _scalar_args(plan, arrs)
            fills = {k: int(v.flat[0]) for k, v in arrs.items()}
            for point in _grid_points(plan):
                idx = op.index_map(*point, *sargs)
                checked += 1
                if not isinstance(idx, tuple):
                    idx = (idx,)
                bad = (len(idx) != len(op.shape))
                if not bad:
                    bad = any(not (0 <= int(i) < nb)
                              for i, nb in zip(idx, nblocks))
                if bad:
                    n = reported.get(op.name, 0)
                    reported[op.name] = n + 1
                    if n < _MAX_REPORTED:
                        res.fail(
                            f"operand {op.name} ({prov}): block index "
                            f"{tuple(int(i) for i in idx)} outside "
                            f"{nblocks} blocks of shape {op.shape} / "
                            f"block {op.block} at grid point {point} "
                            f"with scalars {fills}")
    over = {k: v - _MAX_REPORTED for k, v in reported.items()
            if v > _MAX_REPORTED}
    if over:
        res.fail(f"...and {sum(over.values())} more out-of-bounds block "
                 f"indices suppressed: {over}")
    res.notes.append(f"{checked} (grid point x scalar set x operand) "
                     f"index evaluations, {len(sets)} worst-case scalar "
                     "set(s)")
    return res


# ---------------------------------------------------------------------------
# pass 2: vmem
# ---------------------------------------------------------------------------

def audit_vmem(label: str, plan: LaunchPlan, *,
               budget: int = DEFAULT_VMEM_BUDGET) -> PassResult:
    """Per-program VMEM estimate vs budget.  The estimate is the DMA
    working set: 2x every input/output block (Pallas double-buffers the
    pipeline) + scratch, see ``kernels.plan.estimate_vmem``."""
    res = PassResult("vmem", label)
    est = estimate_vmem(plan)
    blocks = {op.name: op.block_bytes()
              for op in plan.inputs + plan.outputs}
    if est > budget:
        top = sorted(blocks.items(), key=lambda kv: -kv[1])[:3]
        res.fail(f"estimated per-program VMEM {est} B exceeds budget "
                 f"{budget} B (largest blocks: "
                 f"{', '.join(f'{n}={b}B' for n, b in top)}, "
                 f"scratch={plan.scratch_bytes()}B) — shrink the block "
                 "knob (num_splits / block_q / block_r) or raise the "
                 "budget deliberately")
    res.notes.append(f"vmem_est={est} budget={budget} "
                     f"scratch={plan.scratch_bytes()}")
    return res


# ---------------------------------------------------------------------------
# pass 3: revisit / race
# ---------------------------------------------------------------------------

def audit_revisit(label: str, plan: LaunchPlan) -> PassResult:
    """Every output block written from >1 grid step must carry a
    declared accumulation discipline, a ``pl.when``-guarded kernel body,
    and only ``arbitrary``-ordered revisit axes.  Detection runs with
    scalars pinned at max — no output index map in the fleet reads
    scalars, and the grid pass flags any that silently starts to."""
    res = PassResult("revisit", label)
    skip = _bad_arity_ops(plan)
    arrs = scalar_sets(plan)[-1]
    sargs = _scalar_args(plan, arrs)
    try:
        src = inspect.getsource(kernel_source_fn(plan))
    except (OSError, TypeError):
        src = None
        res.notes.append("kernel source unavailable: pl.when discipline "
                         "check skipped")
    revisited = {}
    for op in plan.outputs:
        if op.name in skip:
            continue
        first: dict[tuple, tuple] = {}
        axes: set[int] = set()
        count = 0
        for point in _grid_points(plan):
            idx = op.index_map(*point, *sargs)
            idx = tuple(int(i) for i in (idx if isinstance(idx, tuple)
                                         else (idx,)))
            if idx in first:
                count += 1
                axes.update(a for a, (x, y) in
                            enumerate(zip(first[idx], point)) if x != y)
            else:
                first[idx] = point
        if count:
            revisited[op.name] = sorted(axes)
            if op.name not in plan.accumulate:
                res.fail(
                    f"output {op.name} is written from multiple grid "
                    f"steps (revisit axes {sorted(axes)}) but the plan "
                    "declares no accumulation discipline — silent "
                    "last-write-wins")
                continue
            if src is not None and "pl.when" not in src:
                res.fail(
                    f"output {op.name} declares accumulation "
                    f"'{plan.accumulate[op.name]}' but the kernel body "
                    "has no pl.when guard — no first-write init or "
                    "last-step finalize protects the revisited block")
            if plan.dimension_semantics is not None:
                for a in sorted(axes):
                    if plan.dimension_semantics[a] == "parallel":
                        res.fail(
                            f"output {op.name} is revisited along grid "
                            f"axis {a} which dimension_semantics "
                            "declares 'parallel' — concurrent programs "
                            "would race on the block")
    for name, disc in plan.accumulate.items():
        if name not in revisited and name not in skip:
            res.fail(f"output {name} declares accumulation '{disc}' but "
                     "is never revisited — stale metadata (or the index "
                     "map no longer folds grid steps onto one block)")
    res.notes.append(
        "revisited outputs: "
        + (", ".join(f"{n} (axes {a})" for n, a in revisited.items())
           or "none"))
    return res


# ---------------------------------------------------------------------------
# pass 4: grid / arity
# ---------------------------------------------------------------------------

class _Probe:
    """Stand-in scalar array recording whether an index map indexes it."""

    def __init__(self):
        self.hit = False

    def __getitem__(self, _):
        self.hit = True
        return 0


def audit_grid(label: str, plan: LaunchPlan) -> PassResult:
    res = PassResult("grid", label)
    want = len(plan.grid) + len(plan.scalars)
    if any(g <= 0 for g in plan.grid):
        res.fail(f"degenerate grid {plan.grid}: every axis must be "
                 "positive (zero-size launches route to the reference "
                 "backend in dispatch)")
    names = [op.name for op in plan.inputs + plan.outputs] \
        + [s.name for s in plan.scalars]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        res.fail(f"duplicate operand names {dupes} — audit attribution "
                 "and call_plan operand order would be ambiguous")
    for op in plan.inputs + plan.outputs:
        got = _arity(op.index_map)
        prov = _map_provenance(op.index_map)
        if got is not None and got != want:
            res.fail(f"operand {op.name} ({prov}): index map takes {got} "
                     f"args but grid rank + scalar prefetch is {want}")
        if len(op.block) != len(op.shape):
            res.fail(f"operand {op.name}: block rank {len(op.block)} != "
                     f"operand rank {len(op.shape)}")
            continue
        for d, (b, s) in enumerate(zip(op.block, op.shape)):
            if not 0 < b <= s:
                res.fail(f"operand {op.name}: block dim {d} is {b}, "
                         f"outside (0, {s}] for shape {op.shape}")
    if plan.dimension_semantics is not None \
            and len(plan.dimension_semantics) != len(plan.grid):
        res.fail(f"dimension_semantics rank "
                 f"{len(plan.dimension_semantics)} != grid rank "
                 f"{len(plan.grid)}")
    # which scalar-prefetch operands do the index maps actually read?
    probes = {s.name: _Probe() for s in plan.scalars}
    if plan.scalars:
        zero = (0,) * len(plan.grid)
        pargs = [probes[s.name] for s in plan.scalars]
        for op in plan.inputs + plan.outputs:
            try:
                op.index_map(*zero, *pargs)
            except (TypeError, IndexError):
                pass                      # arity failures flagged above
        for s in plan.scalars:
            if not probes[s.name].hit and not s.kernel_only:
                res.fail(f"scalar-prefetch operand {s.name} is never "
                         "referenced by any BlockSpec index map and is "
                         "not declared kernel_only — dead prefetch "
                         "operand (or a forgotten index map)")
    res.notes.append(
        f"grid {plan.grid}, {len(plan.inputs)} inputs, "
        f"{len(plan.outputs)} outputs, {len(plan.scalars)} scalar "
        f"prefetch ({sum(p.hit for p in probes.values())} referenced by "
        "index maps)")
    return res


# ---------------------------------------------------------------------------
# orchestrators
# ---------------------------------------------------------------------------

def run_plan_audits(plan: LaunchPlan, label: str, *,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET) -> list:
    """All four passes over one launch plan."""
    return [
        audit_bounds(f"{label}/bounds", plan),
        audit_vmem(f"{label}/vmem", plan, budget=vmem_budget),
        audit_revisit(f"{label}/revisit", plan),
        audit_grid(f"{label}/grid", plan),
    ]


def audit_registry(*, vmem_budget: int = DEFAULT_VMEM_BUDGET,
                   registry=None) -> dict:
    """Audit every registered kernel x kv_format x autotune sweep shape.

    Returns the ``kernel_audit`` section of ANALYSIS.json::

        {"budget_bytes": ..., "ok": bool,
         "kernels": {"paged_attn_decode/int8/serving_maxp4_splits2":
                     {"ok": ..., "passes": [...], "vmem_est": ...}, ...}}
    """
    from repro.kernels.dispatch import KERNEL_REGISTRY
    if registry is None:
        registry = KERNEL_REGISTRY
    out = {"budget_bytes": vmem_budget, "kernels": {}, "ok": True}
    for entry in registry.values():
        formats = entry.kv_formats or (None,)
        for case_label, kwargs in entry.audit_cases():
            for fmt in formats:
                kw = dict(kwargs)
                if fmt is not None:
                    kw["kv_format"] = fmt
                label = f"{entry.name}/{fmt or '-'}/{case_label}"
                plan = entry.build_plan(**kw)
                cell = results_to_json(
                    run_plan_audits(plan, label, vmem_budget=vmem_budget))
                cell["vmem_est"] = estimate_vmem(plan)
                out["kernels"][label] = cell
    out["ok"] = all(c["ok"] for c in out["kernels"].values())
    return out

"""AST lint for hot-path hygiene (the static half of analysis/contracts).

Four rules over the ``repro`` source tree, no jax import required:

``host-op``          no ``.item()`` / ``jax.device_get`` / host-numpy
                     (``np.``) attribute use in any function *reachable
                     from a traced root* (the jitted step bodies).  Host
                     math on static shapes belongs to ``math.*`` /
                     builtins; a line may opt out with a
                     ``lint: host-ok`` comment (e.g. genuinely host-side
                     packing helpers that share a file with traced code).
``blockspec-arity``  every Pallas ``BlockSpec`` index map in a function
                     takes exactly ``len(grid) + num_scalar_prefetch``
                     arguments — a wrong arity only explodes at trace
                     time, on TPU, with a Mosaic error.
``static-argnames``  every bool/str-typed parameter of a jitted function
                     appears in ``static_argnames``/``static_argnums``
                     (a traced bool weak-types the whole branch; a traced
                     str is an error only at call time).  Array-typed
                     keyword-only args stay traced, as they must.
``jit-in-loop``      no ``jax.jit(...)`` call syntactically inside a
                     ``for``/``while`` body — a fresh wrapper per
                     iteration re-traces every call (the engine's
                     sequential paged oracle shipped exactly this bug).

A fifth, non-AST rule audits the *checkout* rather than the sources:

``hygiene``          no tracked Python bytecode (``__pycache__/``,
                     ``*.pyc``) in the git index — stale interpreter
                     artifacts shadow source edits in diffs and bloat
                     every clone.  Runs off ``git ls-files``; silently
                     empty outside a git checkout.

Reachability is a conservative over-approximation: module-level and
function-level imports both register, nested defs are scanned with their
parents, and unresolvable calls (third-party, dynamic) are ignored.
"""

from __future__ import annotations

import ast
import subprocess
from dataclasses import dataclass
from pathlib import Path

__all__ = ["LintViolation", "lint_repo", "lint_sources", "hygiene_repo",
           "hygiene_scan", "TRACED_ROOTS", "RULES"]

RULES = ("host-op", "blockspec-arity", "static-argnames", "jit-in-loop",
         "hygiene")

# (path suffix, function) pairs the traced hot paths hang from.  The
# kernels/dispatch entries are listed explicitly because core.bsn
# forwards to them through a lazy same-named import the resolver would
# otherwise self-loop on.
TRACED_ROOTS = (
    ("models/transformer.py", "paged_decode_step"),
    ("models/transformer.py", "paged_prefill"),
    ("models/transformer.py", "prefill"),
    ("models/transformer.py", "decode_step"),
    ("models/transformer.py", "forward"),
    ("serving/sampling.py", "sample_tokens"),
    ("serving/sampling.py", "greedy_tokens"),
    ("kernels/dispatch.py", "approx_bsn"),
    ("kernels/dispatch.py", "paged_attn_decode"),
    ("kernels/dispatch.py", "paged_attn_prefill"),
)

_HOST_OK_MARK = "lint: host-ok"


@dataclass(frozen=True)
class LintViolation:
    file: str
    line: int
    rule: str
    message: str

    def to_dict(self) -> dict:
        return {"file": self.file, "line": self.line, "rule": self.rule,
                "message": self.message}


# ---------------------------------------------------------------------------
# module model
# ---------------------------------------------------------------------------

class _Module:
    def __init__(self, key: str, fname: str, source: str):
        self.key = key                       # dotted module name
        self.fname = fname                   # display path
        self.tree = ast.parse(source, filename=fname)
        self.lines = source.splitlines()
        self.functions: dict[str, ast.AST] = {}
        # alias -> ("module", dotted) | ("symbol", dotted_module, name)
        self.imports: dict[str, tuple] = {}
        self._index()

    def _package(self) -> str:
        parts = self.key.split(".")
        return self.key if self.fname.endswith("__init__.py") \
            else ".".join(parts[:-1])

    def _index(self) -> None:
        pkg = self._package()
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = \
                        ("module", a.name)
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:
                    up = pkg.split(".")
                    up = up[:len(up) - (node.level - 1)]
                    base = ".".join(up + ([node.module]
                                          if node.module else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.imports[a.asname or a.name] = \
                        ("symbol", base, a.name)


def _load_modules(files: dict) -> dict:
    """{display_name: source} -> {dotted key: _Module}.  Keys derive from
    the path: ``.../src/repro/models/moe.py`` -> ``repro.models.moe``."""
    mods = {}
    for fname, src in files.items():
        p = fname.replace("\\", "/")
        if "/repro/" in p:
            rel = "repro/" + p.split("/repro/")[-1]
        else:
            rel = p
        key = rel[:-3] if rel.endswith(".py") else rel
        key = key.replace("/", ".")
        if key.endswith(".__init__"):
            key = key[:-len(".__init__")]
        mods[key] = _Module(key, fname, src)
    return mods


# ---------------------------------------------------------------------------
# reachability (host-op rule)
# ---------------------------------------------------------------------------

def _resolve(mods: dict, modkey: str, name: str, depth: int = 0):
    """Resolve ``name`` in module ``modkey`` to a (modkey, funcname) node,
    following from-import chains (e.g. package __init__ re-exports)."""
    if depth > 8 or modkey not in mods:
        return None
    mod = mods[modkey]
    if name in mod.functions:
        return (modkey, name)
    imp = mod.imports.get(name)
    if imp and imp[0] == "symbol":
        return _resolve(mods, imp[1], imp[2], depth + 1)
    return None


def _call_targets(mods: dict, mod: _Module, fn: ast.AST):
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            tgt = _resolve(mods, mod.key, f.id)
            if tgt:
                out.append(tgt)
        elif isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            base = f.value.id
            if base == "self":
                tgt = _resolve(mods, mod.key, f.attr)
                if tgt:
                    out.append(tgt)
            else:
                imp = mod.imports.get(base)
                if imp and imp[0] == "module":
                    tgt = _resolve(mods, imp[1], f.attr)
                    if tgt:
                        out.append(tgt)
                elif imp and imp[0] == "symbol":
                    # "from repro.kernels import dispatch as kd" registers
                    # as a symbol import of a module — chase it as one
                    tgt = _resolve(mods, f"{imp[1]}.{imp[2]}", f.attr)
                    if tgt:
                        out.append(tgt)
    return out


def _reachable(mods: dict, roots) -> tuple:
    """BFS over the resolved call graph.  Returns (reached set of
    (modkey, fname), list of stale-root violations)."""
    stale, frontier = [], []
    for suffix, fname in roots:
        hit = [m for m in mods.values()
               if m.fname.replace("\\", "/").endswith(suffix)]
        if not hit or fname not in hit[0].functions:
            stale.append(LintViolation(
                suffix, 0, "host-op",
                f"traced root {suffix}:{fname} not found — update "
                "analysis/lint.TRACED_ROOTS"))
            continue
        frontier.append((hit[0].key, fname))
    seen = set()
    while frontier:
        node = frontier.pop()
        if node in seen or node[0] not in mods:
            continue
        seen.add(node)
        mod = mods[node[0]]
        fn = mod.functions.get(node[1])
        if fn is not None:
            frontier.extend(_call_targets(mods, mod, fn))
    return seen, stale


def _numpy_aliases(mod: _Module) -> set:
    return {alias for alias, imp in mod.imports.items()
            if imp == ("module", "numpy")
            or (imp[0] == "symbol" and imp[1] == "numpy")}


def _host_op_scan(mods: dict, reached) -> list:
    vios = []
    for modkey, fname in sorted(reached):
        mod = mods[modkey]
        fn = mod.functions.get(fname)
        np_names = _numpy_aliases(mod)

        def ok_line(line_no: int) -> bool:
            if 1 <= line_no <= len(mod.lines):
                return _HOST_OK_MARK in mod.lines[line_no - 1]
            return False

        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                if not ok_line(node.lineno):
                    vios.append(LintViolation(
                        mod.fname, node.lineno, "host-op",
                        f"{fname}: .item() forces a device->host sync in "
                        "traced-reachable code"))
            elif isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name):
                base, attr = node.value.id, node.attr
                if base == "jax" and attr == "device_get":
                    if not ok_line(node.lineno):
                        vios.append(LintViolation(
                            mod.fname, node.lineno, "host-op",
                            f"{fname}: jax.device_get in traced-reachable "
                            "code"))
                elif base in np_names:
                    if not ok_line(node.lineno):
                        vios.append(LintViolation(
                            mod.fname, node.lineno, "host-op",
                            f"{fname}: host numpy ({base}.{attr}) in "
                            "traced-reachable code — use jnp, or math/"
                            "builtins for static-shape host arithmetic"))
    return vios


# ---------------------------------------------------------------------------
# blockspec-arity rule
# ---------------------------------------------------------------------------

def _attr_tail(f: ast.AST) -> str:
    return f.attr if isinstance(f, ast.Attribute) \
        else (f.id if isinstance(f, ast.Name) else "")


def _callable_arity(node: ast.AST, fn: ast.AST):
    """Positional-arg count of an index map given as a Lambda or a Name
    bound to a lambda / local def inside ``fn``; None if unresolvable."""
    if isinstance(node, ast.Lambda):
        return len(node.args.args)
    if isinstance(node, ast.Name):
        for n in ast.walk(fn):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and n.name == node.id:
                return len(n.args.args)
            if isinstance(n, ast.Assign) \
                    and isinstance(n.value, ast.Lambda) \
                    and any(isinstance(t, ast.Name) and t.id == node.id
                            for t in n.targets):
                return len(n.value.args.args)
    return None


def _pallas_expected_arity(call: ast.Call):
    """len(grid) + num_scalar_prefetch of one pallas_call, or None."""
    grid_len, prefetch = None, 0
    for kw in call.keywords:
        if kw.arg == "grid" and isinstance(kw.value, (ast.Tuple, ast.List)):
            grid_len = len(kw.value.elts)
        elif kw.arg == "grid_spec" and isinstance(kw.value, ast.Call):
            for gkw in kw.value.keywords:
                if gkw.arg == "grid" \
                        and isinstance(gkw.value, (ast.Tuple, ast.List)):
                    grid_len = len(gkw.value.elts)
                elif gkw.arg == "num_scalar_prefetch" \
                        and isinstance(gkw.value, ast.Constant) \
                        and isinstance(gkw.value.value, int):
                    prefetch = gkw.value.value
    return None if grid_len is None else grid_len + prefetch


def _blockspec_scan(mod: _Module) -> list:
    vios = []
    for fn in {id(f): f for f in mod.functions.values()}.values():
        expected = {_pallas_expected_arity(n)
                    for n in ast.walk(fn)
                    if isinstance(n, ast.Call)
                    and _attr_tail(n.func) == "pallas_call"}
        expected.discard(None)
        if len(expected) != 1:
            continue                 # no pallas_call, or ambiguous grids
        want = expected.pop()
        for n in ast.walk(fn):
            if not (isinstance(n, ast.Call)
                    and _attr_tail(n.func) == "BlockSpec"):
                continue
            idx_map = None
            if len(n.args) >= 2:
                idx_map = n.args[1]
            for kw in n.keywords:
                if kw.arg == "index_map":
                    idx_map = kw.value
            if idx_map is None:
                continue
            got = _callable_arity(idx_map, fn)
            if got is not None and got != want:
                vios.append(LintViolation(
                    mod.fname, n.lineno, "blockspec-arity",
                    f"BlockSpec index map takes {got} args but the "
                    f"pallas_call grid rank + num_scalar_prefetch is "
                    f"{want}"))
    return vios


# ---------------------------------------------------------------------------
# static-argnames rule
# ---------------------------------------------------------------------------

def _static_names(call: ast.Call) -> set:
    out = set()
    for kw in call.keywords:
        if kw.arg not in ("static_argnames", "static_argnums"):
            continue
        v = kw.value
        elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
        for e in elts:
            if isinstance(e, ast.Constant):
                out.add(e.value)
    return out


def _is_jax_jit(f: ast.AST) -> bool:
    if isinstance(f, ast.Attribute):
        return f.attr == "jit" and isinstance(f.value, ast.Name) \
            and f.value.id == "jax"
    return isinstance(f, ast.Name) and f.id == "jit"


def _needs_static(arg: ast.arg, default) -> bool:
    """bool/str-typed by annotation or literal default -> must be static.
    Array-typed or unannotated args are assumed traced."""
    if arg.annotation is not None:
        try:
            ann = ast.unparse(arg.annotation)
        except Exception:
            ann = ""
        if "Array" in ann or "array" in ann:
            return False
        return "bool" in ann or "str" in ann
    if isinstance(default, ast.Constant):
        return isinstance(default.value, (bool, str))
    return False


def _check_jitted_def(mod: _Module, fndef, statics: set, line: int) -> list:
    vios = []
    a = fndef.args
    if isinstance(fndef, ast.Lambda):
        return vios                         # lambdas can't annotate
    pos = list(a.posonlyargs) + list(a.args)
    pos_defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    for i, (arg, d) in enumerate(zip(pos, pos_defaults)):
        if arg.arg == "self":
            continue
        if _needs_static(arg, d) and arg.arg not in statics \
                and i not in statics:
            vios.append(LintViolation(
                mod.fname, line, "static-argnames",
                f"jitted fn '{fndef.name}': bool/str arg '{arg.arg}' not "
                "in static_argnames — it would be traced"))
    for arg, d in zip(a.kwonlyargs, a.kw_defaults):
        if _needs_static(arg, d) and arg.arg not in statics:
            vios.append(LintViolation(
                mod.fname, line, "static-argnames",
                f"jitted fn '{fndef.name}': bool/str keyword arg "
                f"'{arg.arg}' not in static_argnames — it would be "
                "traced"))
    return vios


def _static_argnames_scan(mod: _Module) -> list:
    vios = []
    for node in ast.walk(mod.tree):
        # jax.jit(f, ...) call form
        if isinstance(node, ast.Call) and _is_jax_jit(node.func) \
                and node.args:
            target = node.args[0]
            statics = _static_names(node)
            name = None
            if isinstance(target, ast.Name):
                name = target.id
            elif isinstance(target, ast.Attribute):
                name = target.attr
            if name and name in mod.functions:
                vios += _check_jitted_def(mod, mod.functions[name],
                                          statics, node.lineno)
        # @partial(jax.jit, ...) / @jax.jit decorator form
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                statics = None
                if isinstance(dec, ast.Call) \
                        and _attr_tail(dec.func) == "partial" \
                        and dec.args and _is_jax_jit(dec.args[0]):
                    statics = _static_names(dec)
                elif _is_jax_jit(dec):
                    statics = set()
                if statics is not None:
                    vios += _check_jitted_def(mod, node, statics,
                                              node.lineno)
    return vios


# ---------------------------------------------------------------------------
# jit-in-loop rule
# ---------------------------------------------------------------------------

def _jit_in_loop_scan(mod: _Module) -> list:
    vios = []

    def walk(node, in_loop: bool):
        for child in ast.iter_child_nodes(node):
            inner = in_loop or isinstance(
                child, (ast.For, ast.AsyncFor, ast.While))
            if isinstance(child, ast.Call) and _is_jax_jit(child.func) \
                    and in_loop:
                vios.append(LintViolation(
                    mod.fname, child.lineno, "jit-in-loop",
                    "jax.jit(...) constructed inside a loop — every "
                    "iteration builds a fresh wrapper and re-traces; "
                    "hoist it (or key a cache on the static args)"))
            walk(child, inner)

    walk(mod.tree, False)
    return vios


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint_sources(files: dict, roots=()) -> list:
    """Lint a {filename: source} mapping.  ``roots`` (suffix, fn) pairs
    seed the host-op reachability walk; with no roots only the three
    purely syntactic rules run."""
    mods = _load_modules(files)
    vios = []
    if roots:
        reached, stale = _reachable(mods, roots)
        vios += stale
        vios += _host_op_scan(mods, reached)
    for mod in mods.values():
        vios += _blockspec_scan(mod)
        vios += _static_argnames_scan(mod)
        vios += _jit_in_loop_scan(mod)
    return sorted(vios, key=lambda v: (v.file, v.line, v.rule))


def hygiene_scan(tracked_paths) -> list:
    """Flag tracked-bytecode paths in an iterable of repo-relative paths
    (the pure half of ``hygiene_repo``, for tests)."""
    vios = []
    for f in tracked_paths:
        f = f.replace("\\", "/")
        if f.endswith(".pyc") or "__pycache__/" in f:
            vios.append(LintViolation(
                f, 0, "hygiene",
                "tracked Python bytecode — `git rm --cached` it; "
                "__pycache__/ and *.pyc are covered by the root "
                ".gitignore"))
    return vios


def hygiene_repo(repo_root: Path | str | None = None) -> list:
    """Repo-hygiene check over the git index (see module docstring)."""
    if repo_root is None:
        repo_root = Path(__file__).resolve().parents[3]
    try:
        out = subprocess.run(["git", "ls-files"], cwd=str(repo_root),
                             capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError):
        return []            # not a git checkout (sdist / wheel install)
    return hygiene_scan(out.stdout.splitlines())


def lint_repo(src_root: Path | str | None = None,
              roots=TRACED_ROOTS) -> list:
    """Lint every ``repro/**/*.py`` under ``src_root`` (defaults to the
    package's own source tree)."""
    if src_root is None:
        src_root = Path(__file__).resolve().parent.parent
    src_root = Path(src_root)
    files = {}
    for p in sorted(src_root.rglob("*.py")):
        try:
            files[str(p)] = p.read_text(encoding="utf-8")
        except (OSError, UnicodeDecodeError):
            continue
    return lint_sources(files, roots=roots)

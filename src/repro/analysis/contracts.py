"""Hot-path contract checker: static audits of the engine's jitted steps.

The serving engine's correctness/speed story rests on invariants that
benchmarks only rediscover after they regress.  This module checks them
*statically*, from three artifacts jax hands us for free:

* the **jaxpr** of each traced step (dtype purity, host boundary),
* the **lowered MLIR** (`tf.aliasing_output` arg attributes — donation),
* the **compiled HLO** (collective wire bytes via
  :mod:`repro.analysis.hlo_cost`, the shared parser).

Pass families (see analysis/README.md for the catalog and the allowlist
policy):

``donation``   every cache leaf the engine donates actually aliases an
               output buffer — a silent donation failure doubles HBM.
``retrace``    re-running an identical workload adds zero lowerings
               (catches weak-type promotion, python-scalar closures and
               per-call ``jax.jit(lambda ...)`` wrappers).
``dtype``      no float ``dot_general``/``convolution`` inside the
               sc_int / sc_int_approx BSN region; float math is allowed
               only in the attention/recurrence/softmax/norm/sampler
               allowlist, and the integer datapath must actually be
               engaged.
``host``       no callback / infeed / device_put primitive inside a
               jitted hot-path trace.
``sharding``   under mesh rules, every pool leaf carries the sharding
               ``paged_cache_specs`` promises, and compiled decode stays
               within a collective wire-bytes budget.

Everything here is read-only: audits never execute a step (the sharding
budget compiles decode but does not run it).  ``tools/analyze.py`` drives
these over the config x datapath x kv_format matrix and gates CI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .hlo_cost import analyze_hlo

__all__ = [
    "Violation", "PassResult", "iter_eqns", "eqn_provenance",
    "audit_donation", "audit_dtype_purity", "audit_host_boundary",
    "audit_sharding", "audit_engine_retrace", "decode_example_args",
    "prefill_example_args", "run_engine_contracts", "results_to_json",
    "FLOAT_DOT_ALLOW_FILES", "FLOAT_DOT_ALLOW_FUNCS",
]


@dataclass(frozen=True)
class Violation:
    passname: str          # donation | retrace | dtype | host | sharding
    label: str             # which lowering, e.g. "granite/sc_int/fp/decode"
    message: str

    def to_dict(self) -> dict:
        return {"pass": self.passname, "label": self.label,
                "message": self.message}


@dataclass
class PassResult:
    passname: str
    label: str
    violations: list = field(default_factory=list)
    notes: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def fail(self, message: str) -> None:
        self.violations.append(Violation(self.passname, self.label, message))

    def to_dict(self) -> dict:
        return {"pass": self.passname, "label": self.label, "ok": self.ok,
                "violations": [v.to_dict() for v in self.violations],
                "notes": list(self.notes)}


def results_to_json(results: list) -> dict:
    vios = [v for r in results for v in r.violations]
    return {"ok": not vios,
            "passes": [r.to_dict() for r in results],
            "violation_count": len(vios)}


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: dict) -> list:
    """Sub-jaxprs buried in an eqn's params (scan/while/pjit/cond/pallas),
    duck-typed so no deprecated jax.core symbols are touched."""
    out = []

    def visit(v):
        if hasattr(v, "eqns"):                        # Jaxpr
            out.append(v)
        elif hasattr(v, "jaxpr") and hasattr(getattr(v, "jaxpr"), "eqns"):
            out.append(v.jaxpr)                       # ClosedJaxpr
        elif isinstance(v, (list, tuple)):
            for x in v:
                visit(x)
        elif isinstance(v, dict):
            for x in v.values():
                visit(x)

    for v in params.values():
        visit(v)
    return out


def iter_eqns(jaxpr):
    """Yield every eqn of ``jaxpr`` (Jaxpr or ClosedJaxpr), recursing into
    scan/while/cond/pjit/custom-call/pallas sub-jaxprs."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    seen, stack = set(), [jaxpr]
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            yield eqn
            stack.extend(_sub_jaxprs(eqn.params))


def eqn_provenance(eqn) -> str:
    """Innermost repro-source frame of an eqn, as ``"file.py:function"``
    (path relative to the ``repro`` package).  ``"<external>"`` when the
    traceback never enters the repo (e.g. pure-jax helper eqns)."""
    try:
        tb = eqn.source_info.traceback
        frames = tb.frames if tb is not None else []
    except AttributeError:
        frames = []
    for f in frames:
        fn = str(f.file_name).replace("\\", "/")
        if "/repro/" in fn and "/analysis/" not in fn:
            return f"{fn.split('/repro/')[-1]}:{f.function_name}"
    return "<external>"


# ---------------------------------------------------------------------------
# pass 1: donation
# ---------------------------------------------------------------------------

_MLIR_DTYPE = {
    "float32": "f32", "float64": "f64", "float16": "f16",
    "bfloat16": "bf16", "int8": "i8", "int16": "i16", "int32": "i32",
    "int64": "i64", "uint8": "ui8", "uint16": "ui16", "uint32": "ui32",
    "uint64": "ui64", "bool": "i1",
}


def _mlir_type(ai) -> str:
    dt = _MLIR_DTYPE.get(str(np.dtype(ai.dtype)), str(ai.dtype))
    dims = "x".join(str(d) for d in ai.shape)
    return f"{dims}x{dt}" if dims else dt


def _parse_mlir_main_args(mlir: str) -> list:
    """(index, tensor type, has tf.aliasing_output) per %argN of @main."""
    m = re.search(r"func\.func\s+(?:public\s+)?@\w+\(", mlir)
    if not m:
        return []
    start = m.end() - 1
    depth = 0
    end = start
    for i in range(start, len(mlir)):
        if mlir[i] == "(":
            depth += 1
        elif mlir[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    sig = mlir[start + 1:end]
    out = []
    for chunk in sig.split("%arg")[1:]:
        idx = re.match(r"(\d+)", chunk)
        typ = re.search(r"tensor<([^>]*)>", chunk)
        out.append((int(idx.group(1)), typ.group(1) if typ else "",
                    "tf.aliasing_output" in chunk))
    return out


def audit_donation(label: str, lowered, *,
                   donated_prefix: str = "[0][1]") -> PassResult:
    """Every arg leaf under ``donated_prefix`` (the keystr path prefix of
    the donated cache argument) must be (a) marked donated in
    ``args_info`` and (b) actually aliased to an output in the lowered
    MLIR (``tf.aliasing_output``).  The default prefix is the second
    positional arg — ``args_info`` is an ((args...), {kwargs}) pytree, so
    the engine's donated cache lives at ``[0][1]``.  (a) catches a dropped
    ``donate_argnums``; (b) catches donation silently falling through
    (shape/dtype/sharding mismatch between the donated input and every
    output — jax only warns, and nobody reads serving logs)."""
    res = PassResult("donation", label)
    leaves = jax.tree_util.tree_flatten_with_path(lowered.args_info)[0]
    paths = [(jax.tree_util.keystr(kp), ai) for kp, ai in leaves]
    donated = [(p, ai) for p, ai in paths if p.startswith(donated_prefix)]
    if not donated:
        res.fail(f"no arg leaves under path {donated_prefix!r} — wrong "
                 "donated-arg prefix or empty cache pytree")
        return res
    for p, ai in donated:
        if not ai.donated:
            res.fail(f"cache leaf {p} is not marked for donation "
                     "(donate_argnums does not cover it)")
    mlir_args = _parse_mlir_main_args(lowered.as_text())
    if not mlir_args:
        res.fail("could not parse @main signature from lowered MLIR")
        return res
    if len(mlir_args) == len(paths):
        # 1:1 positional mapping between flat arg leaves and MLIR args
        by_pos = {i: al for (i, _, al), _ in zip(mlir_args, paths)}
        for i, (p, ai) in enumerate(paths):
            if p.startswith(donated_prefix) and ai.donated \
                    and not by_pos.get(i, False):
                res.fail(f"donated cache leaf {p} has no "
                         "tf.aliasing_output in the lowered MLIR — "
                         "donation fell through (no output aliases it)")
    else:
        # donated-but-unused leaves get DCE'd out of @main; fall back to
        # type-multiset accounting so the audit stays sound
        from collections import Counter
        want = Counter(_mlir_type(ai) for p, ai in donated if ai.donated)
        have = Counter(t for _, t, al in mlir_args if al)
        for t, n in want.items():
            if have.get(t, 0) < n:
                res.fail(f"{n - have.get(t, 0)} donated cache leaves of "
                         f"type tensor<{t}> missing from the lowered "
                         "MLIR aliasing set — donation fell through")
        res.notes.append(
            f"arg-count mismatch (flat {len(paths)} vs MLIR "
            f"{len(mlir_args)}): DCE'd donated leaves; checked by "
            "type multiset")
    aliased = sum(1 for _, _, al in mlir_args if al)
    res.notes.append(f"{len(donated)} cache leaves under {donated_prefix}, "
                     f"{aliased} MLIR args aliased")
    return res


# ---------------------------------------------------------------------------
# pass 2: dtype purity
# ---------------------------------------------------------------------------

# Float dots are ALLOWED only where the paper keeps float math: the
# attention score/value contractions (softmax is float by definition),
# the recurrent mixers' state updates (SSM/WKV recurrences are not BSN
# accumulations), and the sampler.  The projection modules — common.py
# dense_apply, core/sc_layers.py, moe.py expert matmuls — ARE the BSN
# region: a float dot attributed there is a precision leak.
FLOAT_DOT_ALLOW_FILES = (
    "kernels/paged_attention.py", "kernels/flash_attention.py",
    "kernels/ref.py", "models/attention.py", "models/mamba.py",
    "models/rwkv6.py", "serving/sampling.py",
)
# function-level allows: the MoE router draws its gate in f32 by design
# (outside the quantized datapath); expert matmuls are NOT allowed.
FLOAT_DOT_ALLOW_FUNCS = (
    ("models/moe.py", "moe_apply"),
)

_DOT_PRIMS = ("dot_general", "conv_general_dilated")


def audit_dtype_purity(label: str, jaxpr, *, datapath: str) -> PassResult:
    """No float dot/conv inside the integer BSN region (sc_int /
    sc_int_approx), plus a positive check that the integer datapath was
    actually engaged (an audit that passes because quantization silently
    turned itself off is worse than no audit)."""
    res = PassResult("dtype", label)
    if datapath == "qat":
        res.notes.append("qat datapath: float projections are the "
                         "datapath — purity not applicable")
        return res
    float_dots, int_dots, sc_eqns = [], [], 0
    for eqn in iter_eqns(jaxpr):
        prov = eqn_provenance(eqn)
        if prov.startswith("core/sc_layers.py") \
                or prov.startswith("core/bsn.py"):
            sc_eqns += 1
        if eqn.primitive.name not in _DOT_PRIMS:
            continue
        try:
            dt = eqn.outvars[0].aval.dtype
        except (AttributeError, IndexError):
            continue
        if jnp.issubdtype(dt, jnp.floating):
            float_dots.append((prov, str(dt), eqn.primitive.name))
        else:
            int_dots.append(prov)
    for prov, dt, prim in float_dots:
        f, _, fn = prov.partition(":")
        if any(f.endswith(a) for a in FLOAT_DOT_ALLOW_FILES):
            continue
        if any(f.endswith(af) and fn == an
               for af, an in FLOAT_DOT_ALLOW_FUNCS):
            continue
        res.fail(f"float {prim} ({dt}) at {prov} inside the {datapath} "
                 "BSN region — not in the float-math allowlist "
                 "(analysis/README.md)")
    if datapath == "sc_int":
        engaged = [p for p in int_dots if p.startswith("core/sc_layers.py")]
        if not engaged:
            res.fail("sc_int datapath produced no integer dot from "
                     "core/sc_layers.py — the integer datapath is not "
                     "engaged (quantization silently off?)")
    elif datapath == "sc_int_approx" and sc_eqns == 0:
        res.fail("sc_int_approx datapath produced no ops attributed to "
                 "core/sc_layers.py or core/bsn.py — the approximate "
                 "BSN datapath is not engaged")
    res.notes.append(f"{len(float_dots)} float dots (allowlisted), "
                     f"{len(int_dots)} integer dots")
    return res


# ---------------------------------------------------------------------------
# pass 3: host boundary
# ---------------------------------------------------------------------------

_HOST_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "host_callback", "outside_call", "device_put",
})


def audit_host_boundary(label: str, jaxpr) -> PassResult:
    """No host-boundary primitive inside a jitted hot-path trace: every
    callback / infeed / device_put is a device->host (or host->device)
    sync that serializes the decode loop."""
    res = PassResult("host", label)
    count = 0
    for eqn in iter_eqns(jaxpr):
        count += 1
        if eqn.primitive.name in _HOST_PRIMS:
            res.fail(f"host-boundary primitive {eqn.primitive.name} at "
                     f"{eqn_provenance(eqn)} inside a jitted hot path")
    res.notes.append(f"scanned {count} eqns")
    return res


# ---------------------------------------------------------------------------
# pass 4: sharding coverage
# ---------------------------------------------------------------------------

def audit_sharding(eng, label: str, *, cache=None,
                   wire_budget_mult: float = 8.0,
                   check_collectives: bool = True) -> PassResult:
    """Under mesh rules: every paged-cache leaf carries exactly the
    sharding ``paged_cache_specs`` promises (resolved through the rules
    and ``fit_spec``, so non-dividing axes are *expected* replicated),
    and compiled decode stays within a collective wire-bytes budget of
    ``mult x (logits gather + per-layer activation reductions)``."""
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import fit_spec
    from repro.models import paged_cache_specs

    res = PassResult("sharding", label)
    if eng.rules is None:
        res.notes.append("no mesh rules: sharding audit skipped")
        return res
    mesh = eng.rules.mesh
    cache = eng.cache if cache is None else cache
    spec_tree = paged_cache_specs(eng.cfg, eng.kv_format)
    is_spec = lambda s: s is None or isinstance(s, tuple)  # noqa: E731
    cache_leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    spec_leaves = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec)[0]
    assert len(cache_leaves) == len(spec_leaves), \
        "cache / spec tree mismatch"
    sharded = 0
    for (kp, arr), (_, lg) in zip(cache_leaves, spec_leaves):
        from jax.sharding import PartitionSpec as P
        spec = eng.rules.resolve(lg) if lg is not None else P()
        spec = fit_spec(spec, arr.shape, mesh)
        want = NamedSharding(mesh, spec)
        actual = getattr(arr, "sharding", None)
        if actual is None or not actual.is_equivalent_to(want, arr.ndim):
            res.fail(f"cache leaf {jax.tree_util.keystr(kp)}: sharding "
                     f"{getattr(actual, 'spec', actual)} != expected "
                     f"{spec} (paged_cache_specs through the mesh rules)")
        elif any(ax is not None for ax in spec):
            sharded += 1
    res.notes.append(f"{len(cache_leaves)} cache leaves checked, "
                     f"{sharded} sharded, mesh "
                     f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if not check_collectives:
        return res

    args = decode_example_args(eng)
    with eng._scope():
        compiled = eng._decode.lower(eng.params, eng.cache, *args,
                                     do_sample=False).compile()
    cost = analyze_hlo(compiled.as_text())
    cfg = eng.cfg
    S = int(args[0].shape[0])
    vpad = getattr(cfg, "vocab_pad_multiple", 1) or 1
    V = -(-cfg.vocab_size // vpad) * vpad
    # one logits gather + up to 4 activation reductions per layer, f32
    budget = wire_budget_mult * 4.0 * S * (V + 4 * cfg.n_layers
                                           * cfg.d_model)
    wire = cost.total_collective_bytes
    if wire > budget:
        res.fail(f"decode collective wire bytes {wire:.0f} exceed budget "
                 f"{budget:.0f} ({cost.collective_count} collectives: "
                 f"{cost.collective_bytes}) — a pool or weight is being "
                 "re-gathered every step")
    res.notes.append(f"decode wire bytes {wire:.0f} / budget "
                     f"{budget:.0f} ({cost.collective_count} collectives)")
    return res


# ---------------------------------------------------------------------------
# pass 5: retrace
# ---------------------------------------------------------------------------

def audit_engine_retrace(eng, prompts, label: str, *,
                         max_new: int = 4,
                         max_decode_lowerings: int | None = None,
                         max_prefill_lowerings: int | None = None
                         ) -> PassResult:
    """Run a prompt ladder twice through a live engine: the second,
    byte-identical pass must add ZERO lowerings to the decode/prefill jit
    caches (a growth means something non-hashable-by-shape leaked into
    the trace: weak types, python scalars, per-call wrappers).  Optional
    absolute ceilings pin the pow2 bucket ladder count itself."""
    res = PassResult("retrace", label)
    fns = {"decode": eng._decode, "prefill": eng._prefill_batched}
    if not all(hasattr(f, "_cache_size") for f in fns.values()):
        res.notes.append("jit cache size introspection unavailable on "
                         "this jax: retrace audit skipped")
        return res

    def run():
        for p in prompts:
            eng.submit(list(p), max_new_tokens=max_new)
        eng.run_to_completion()

    run()
    first = {k: f._cache_size() for k, f in fns.items()}
    run()
    second = {k: f._cache_size() for k, f in fns.items()}
    for k in fns:
        if second[k] > first[k]:
            res.fail(f"{k} retraced on an identical repeated workload: "
                     f"{first[k]} -> {second[k]} lowerings (non-static "
                     "value leaked into the trace key)")
    caps = {"decode": max_decode_lowerings, "prefill": max_prefill_lowerings}
    for k, cap in caps.items():
        if cap is not None and first[k] > cap:
            res.fail(f"{k} traced {first[k]} lowerings for the bucket "
                     f"ladder, expected <= {cap} (one per pow2 bucket)")
    res.notes.append(f"lowerings after ladder: decode {first['decode']}, "
                     f"prefill {first['prefill']}; stable on repeat")
    return res


# ---------------------------------------------------------------------------
# example args + orchestrator
# ---------------------------------------------------------------------------

def decode_example_args(eng, lanes: int = 2):
    """(tokens, slot_ids, tables, lengths, samp) for one decode-step
    lowering at a representative (pow2) bucket.  Values are all zeros /
    trash pages — audits only trace, never execute."""
    from repro.serving.paging import pad_pow2
    from repro.serving.sampling import SamplingParams, pack_sampling
    S = min(pad_pow2(lanes), pad_pow2(eng.max_slots))
    width = pad_pow2(min(4, eng.max_pages))
    return (jnp.zeros((S,), jnp.int32),
            jnp.zeros((S,), jnp.int32),
            jnp.zeros((S, width), jnp.int32),
            jnp.zeros((S,), jnp.int32),
            pack_sampling([SamplingParams()] * S))


def prefill_example_args(eng, lanes: int = 2):
    """((tokens, tables, lens, slot_ids, samp), chunk) for one batched
    chunked-prefill lowering.  L and chunk are pow2 multiples of the page
    size (paged_prefill asserts page alignment)."""
    from repro.serving.paging import pad_pow2
    from repro.serving.sampling import SamplingParams, pack_sampling
    G = min(pad_pow2(lanes), pad_pow2(eng.max_slots))
    L = pad_pow2(eng.page_size)
    if 2 * L <= eng.max_len:
        L *= 2                                    # two pages when they fit
    chunk = min(eng._chunk, L)
    width = max(L // eng.page_size, 1)
    args = (jnp.zeros((G, L), jnp.int32),
            jnp.zeros((G, width), jnp.int32),
            jnp.ones((G,), jnp.int32),
            jnp.zeros((G,), jnp.int32),
            pack_sampling([SamplingParams()] * G))
    return args, chunk


def run_engine_contracts(eng, label: str, *,
                         check_collectives: bool = True) -> list:
    """Static audit battery for one constructed engine: donation +
    dtype + host over decode, batched prefill and the sampler, plus the
    sharding audit under mesh rules.  Returns a list of PassResults and
    never executes a step.  The exact-prefill debug oracle is donation-
    exempt BY DESIGN (it takes no cache input — it builds a fresh
    exact-length cache; see ServeEngine.__init__), recorded as a note so
    the exemption stays visible in ANALYSIS.json."""
    from repro.serving.sampling import sample_tokens

    d_args = decode_example_args(eng)
    p_args, chunk = prefill_example_args(eng)
    with eng._scope():
        dec_low = eng._decode.lower(eng.params, eng.cache, *d_args,
                                    do_sample=False)
        pre_low = eng._prefill_batched.lower(eng.params, eng.cache,
                                             *p_args, chunk=chunk,
                                             do_sample=False)
        dec_jx = jax.make_jaxpr(partial(eng._decode_fn, do_sample=False))(
            eng.params, eng.cache, *d_args)
        pre_jx = jax.make_jaxpr(
            partial(eng._prefill_batched_fn, chunk=chunk,
                    do_sample=False))(eng.params, eng.cache, *p_args)
        S = d_args[0].shape[0]
        samp_jx = jax.make_jaxpr(
            lambda lg, pos, sm: sample_tokens(lg, pos, sm,
                                              eng.cfg.vocab_size))(
            jnp.zeros((S, eng.cfg.vocab_size), jnp.float32),
            jnp.zeros((S,), jnp.int32), d_args[4])

    results = [
        audit_donation(f"{label}/decode", dec_low),
        audit_donation(f"{label}/prefill", pre_low),
        audit_dtype_purity(f"{label}/decode", dec_jx,
                           datapath=eng.datapath),
        audit_dtype_purity(f"{label}/prefill", pre_jx,
                           datapath=eng.datapath),
        audit_host_boundary(f"{label}/decode", dec_jx),
        audit_host_boundary(f"{label}/prefill", pre_jx),
        audit_host_boundary(f"{label}/sampler", samp_jx),
        audit_sharding(eng, f"{label}/sharding",
                       check_collectives=check_collectives),
    ]
    exempt = PassResult("donation", f"{label}/prefill_exact")
    exempt.notes.append(
        "exempt by design: the exact-prefill debug oracle takes "
        "(params, batch) only and BUILDS a fresh exact-length cache — "
        "there is no input cache buffer to alias into")
    results.append(exempt)
    return results

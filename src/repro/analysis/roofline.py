"""Three-term roofline from a compiled dry-run artifact (deliverable g).

    compute    = FLOPs_per_device / peak_FLOPS
    memory     = HBM_bytes_per_device / HBM_bw
    collective = wire_bytes_per_device / link_bw

FLOPs/bytes come from the trip-count-aware HLO walk (hlo_cost.py);
``compiled.cost_analysis()`` numbers are recorded alongside for reference
(they undercount scan bodies — §Roofline methodology in EXPERIMENTS.md).
Formula note: the assignment's ``collective_bytes / (chips x link_bw)``
with *global* collective bytes equals our per-device wire bytes / link_bw
— the same quantity, computed shard-locally.

MODEL_FLOPS is the analytic 6·N·D (train) / 2·N·D (prefill/decode) with
active-N for MoE; the ratio MODEL_FLOPS / HLO_FLOPS exposes remat /
dispatch / quantization overhead.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

from repro.configs.base import ModelConfig, ShapeConfig

from .hlo_cost import HloCost, analyze_hlo

__all__ = ["V5E", "RooflineReport", "roofline_from_compiled",
           "count_params", "model_flops"]


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float
    peak_flops_int8: float
    hbm_bw: float
    link_bw: float
    hbm_bytes: float


V5E = HwSpec(name="tpu-v5e", peak_flops_bf16=197e12,
             peak_flops_int8=394e12, hbm_bw=819e9, link_bw=50e9,
             hbm_bytes=16e9)


# ---------------------------------------------------------------------------
# analytic parameter / FLOP counts
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> float:
    """Analytic parameter count (matmul weights; norms/scales ignored)."""
    d, dh = cfg.d_model, cfg.head_dim
    total = 2.0 * cfg.padded_vocab * d              # embed + head
    for spec in cfg.period:
        if spec.mixer == "attn":
            total_l = d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        elif spec.mixer == "mamba":
            din, n, r = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank
            total_l = d * 2 * din + din * (r + 2 * n) + r * din + din * d
        elif spec.mixer == "rwkv6":
            total_l = 5 * d * d                      # r,k,v,g,o
        else:
            total_l = 0
        if spec.ffn == "dense":
            total_l += d * cfg.d_ff * (3 if cfg.ffn_gated else 2)
        elif spec.ffn == "moe":
            e = (cfg.n_experts_per_tok if active_only else cfg.n_experts)
            total_l += e * d * cfg.d_ff * (3 if cfg.ffn_gated else 2) \
                + d * cfg.n_experts
        elif spec.ffn == "rwkv_cmix":
            total_l += d * cfg.d_ff * 2 + d * d
        total += total_l * cfg.n_periods
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D train; 2·N_active·D forward (decode: D = new tokens)."""
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch       # decode: 1 tok/seq


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device, from the trip-count-aware HLO walk
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    collective_breakdown: dict
    # raw XLA numbers for reference
    xla_flops: float
    xla_bytes: float
    # memory fit
    peak_hbm_bytes: float
    argument_bytes: float
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    # analytics
    model_flops_total: float = 0.0
    useful_flops_ratio: float = 0.0
    bottleneck: str = ""
    roofline_fraction: float = 0.0
    fits_hbm: bool = True
    note: str = ""

    def finalize(self, hw: HwSpec):
        self.t_compute = self.flops_per_device / hw.peak_flops_bf16
        self.t_memory = self.hbm_bytes_per_device / hw.hbm_bw
        self.t_collective = self.wire_bytes_per_device / hw.link_bw
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        t_bound = max(terms.values())
        if t_bound > 0:
            # fraction of the dominant-bound time that is useful model math
            useful_t = (self.model_flops_total / self.n_chips) \
                / hw.peak_flops_bf16
            self.roofline_fraction = min(useful_t / t_bound, 1.0)
        if self.flops_per_device > 0:
            self.useful_flops_ratio = (self.model_flops_total / self.n_chips) \
                / self.flops_per_device
        self.fits_hbm = self.peak_hbm_bytes <= hw.hbm_bytes
        return self

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def roofline_from_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig,
                           mesh_name: str, n_chips: int,
                           hw: HwSpec = V5E) -> RooflineReport:
    cost: HloCost = analyze_hlo(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    peak = getattr(ma, "peak_memory_in_bytes", 0) if ma else 0
    args = getattr(ma, "argument_size_in_bytes", 0) if ma else 0
    rep = RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.bytes,
        wire_bytes_per_device=cost.total_collective_bytes,
        collective_breakdown=dict(cost.collective_bytes),
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
        peak_hbm_bytes=float(peak) if peak else float(args),
        argument_bytes=float(args),
        model_flops_total=model_flops(cfg, shape),
    )
    return rep.finalize(hw)

"""Trip-count-aware HLO cost model (the dry-run "profiler").

``compiled.cost_analysis()`` counts each ``while`` body ONCE — a 94-layer
scan-over-layers model would be undercounted ~94x, and flash-attention /
selective-scan / token-scan bodies compound the error (verified
empirically; see EXPERIMENTS.md §Roofline methodology).  This module
re-derives FLOPs / HBM bytes / collective wire-bytes by walking the
post-SPMD HLO text:

* ``while`` ops multiply their (body + cond) cost by the trip count,
  recovered from the loop-bound ``s32 constant`` in the condition
  computation (jax scans always lower to counted loops);
* ``fusion`` ops contribute their *internal* FLOPs but only their
  boundary bytes (VMEM-resident intermediates don't touch HBM);
* collectives are tallied separately with a ring-model wire-bytes
  estimate using the replica-group size.

All shapes in a post-partitioning module are per-shard, so every number
this produces is PER-DEVICE.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo", "parse_computations"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops costed at ~1 flop per output element (everything heavier is dot/conv)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "logistic", "log", "rsqrt", "sqrt", "negate",
    "abs", "floor", "ceil", "round-nearest-afz", "compare", "select",
    "and", "or", "xor", "not", "clamp", "sign", "cosine", "sine", "atan2",
    "expm1", "log1p", "reduce", "reduce-window", "erf",
}


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)   # kind -> wire bytes
    collective_count: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) \
                + v * mult
        self.collective_count += int(other.collective_count * mult)


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

def _shape_bytes(type_str: str) -> float:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0.0
    # tuple: sum each component
    for m in re.finditer(r"([a-z][a-z0-9]*)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = re.search(r"[a-z][a-z0-9]*\[([\d,]*)\]", type_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def _match_paren(s: str, start: int) -> int:
    """Index of the paren matching s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _operand_name(raw: str) -> str:
    """Normalize one operand to its instruction name.

    Depending on the XLA version the printer emits operands bare
    (``%add.3``) or typed (``f32[64,128]{1,0} %add.3`` — jax >= 0.4.3x).
    Literals (``constant(10)`` bodies) have no ``%`` and pass through."""
    m = None
    for m in re.finditer(r"%([\w\.\-]+)", raw):
        pass
    return m.group(1) if m else raw.lstrip("%")


def _parse_instr(line: str) -> Instr | None:
    """Manual instruction parser — regexes break on tuple types that embed
    ``/*index=5*/`` comments (i.e. every big while loop's carry)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):                      # tuple type
        close = _match_paren(rest, 0)
        tstr, rest2 = rest[:close + 1], rest[close + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        tstr, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    par = rest2.find("(")
    if par <= 0:
        return None
    op = rest2[:par].strip()
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    close = _match_paren(rest2, par)
    operands = [_operand_name(o)
                for o in _split_operands(rest2[par + 1:close])]
    attrs = rest2[close + 1:]
    return Instr(name, tstr, op, operands, attrs)


def parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    current: list[Instr] | None = None
    for line in text.splitlines():
        header = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{",
                          line)
        if header and not line.lstrip().startswith("//"):
            current = []
            comps[header.group(1)] = current
            if "ENTRY" in line:
                comps["__entry__"] = current
            continue
        if current is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            current.append(ins)
    return comps


def _split_operands(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [o for o in (x.strip() for x in out) if o]


# ---------------------------------------------------------------------------
# cost walk
# ---------------------------------------------------------------------------

def _trip_count(while_attrs: str, cond: list[Instr]) -> int:
    """Trip count of a counted loop.

    Preferred source: the scheduler's ``known_trip_count`` backend config
    on the ``while`` op itself (emitted by every XLA version this repo
    pins).  Fallback: the largest s32 constant in the condition
    computation — jax counted loops compare the induction var LT bound."""
    m = re.search(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"',
                  while_attrs)
    if m:
        return max(int(m.group(1)), 1)
    best = 1
    for ins in cond:
        if ins.op == "constant" and "s32[]" in ins.type_str:
            if ins.operands and ins.operands[0].isdigit():
                best = max(best, int(ins.operands[0]))
    return best


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _collective_wire_bytes(op: str, result_bytes: float, g: int) -> float:
    """Ring-model per-device wire bytes from the (local) result shape."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    if op == "collective-permute":
        return result_bytes
    return 0.0


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(ins.type_str):
        out_elems *= d
    lhs = types.get(ins.operands[0]) if ins.operands else None
    contraction = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    if lhs and m and m.group(1):
        dims = _shape_dims(lhs)
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                contraction *= dims[i]
    return 2.0 * out_elems * contraction


def _conv_flops(ins: Instr, types: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(ins.type_str):
        out_elems *= d
    rhs = types.get(ins.operands[1]) if len(ins.operands) > 1 else None
    k = 1
    if rhs:
        for d in _shape_dims(rhs):
            k *= d
    # per output element: 2 * kernel_elems / out_channels (approximation)
    dims = _shape_dims(ins.type_str)
    oc = dims[-1] if dims else 1
    return 2.0 * out_elems * max(k // max(oc, 1), 1)


def _fusion_boundary_bytes(body: list[Instr], result_bytes: float) -> float:
    """HBM traffic at a fusion boundary, region-aware.

    A loop-body fusion often takes the WHOLE carried buffer as an operand
    and slices it internally — the real read is the slice, not the buffer.
    Rule: an internal parameter consumed ONLY by slice/dynamic-slice/gather
    contributes its consumers' result bytes; otherwise its full size.
    Symmetrically, a fusion whose root is dynamic-update-slice writes only
    the update region (the output aliases the input buffer).
    """
    if not body:
        return result_bytes
    by_name = {i.name: i for i in body}
    types = {i.name: i.type_str for i in body}
    consumers: dict[str, list[Instr]] = {}
    for ins in body:
        for o in ins.operands:
            consumers.setdefault(o, []).append(ins)

    _PASS = ("convert", "bitcast", "copy", "reshape", "transpose")
    # XLA-CPU artifact: bf16 dus lowers as convert(full) -> dus f32 ->
    # convert(full); on TPU the dus is native.  Seeing through pass-through
    # chains keeps the TPU roofline honest.

    def final_consumers(name, depth=0):
        out = []
        if depth > 6:
            return out
        for c in consumers.get(name, []):
            if c.op in _PASS:
                out += final_consumers(c.name, depth + 1)
            else:
                out.append((c, name))
        return out

    total = 0.0
    for ins in body:
        if ins.op != "parameter":
            continue
        fc = final_consumers(ins.name)
        if fc and all(c.op in ("dynamic-slice", "slice", "gather")
                      for c, _ in fc):
            total += sum(_shape_bytes(c.type_str) for c, _ in fc)
        elif fc and all(c.op == "dynamic-update-slice"
                        and c.operands and c.operands[0] == via
                        for c, via in fc):
            # in-place update target: aliased, traffic = update region
            # (region read+write accounted at the root below)
            pass
        else:
            total += _shape_bytes(ins.type_str)
    root = body[-1]
    while root.op in _PASS and root.operands and root.operands[0] in by_name:
        root = by_name[root.operands[0]]
    if root.op == "dynamic-update-slice" and len(root.operands) > 1:
        total += 2 * _shape_bytes(types.get(root.operands[1], ""))
    else:
        total += result_bytes
    return total


def _cost_of(comp_name: str, comps: dict, memo: dict) -> HloCost:
    if comp_name in memo:
        return memo[comp_name]
    cost = HloCost()
    instrs = comps.get(comp_name, [])
    types = {i.name: i.type_str for i in instrs}

    for ins in instrs:
        rb = _shape_bytes(ins.type_str)
        ob = sum(_shape_bytes(types.get(o, "")) for o in ins.operands)

        if ins.op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            trips = _trip_count(
                ins.attrs, comps.get(cond.group(1), []) if cond else [])
            if body:
                cost.add(_cost_of(body.group(1), comps, memo), trips)
            if cond:
                cost.add(_cost_of(cond.group(1), comps, memo), trips)
        elif ins.op == "fusion":
            called = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
            if called:
                inner = _cost_of(called.group(1), comps, memo)
                # fusion: internal flops count, internal bytes don't
                cost.flops += inner.flops
                for k, v in inner.collective_bytes.items():
                    cost.collective_bytes[k] = \
                        cost.collective_bytes.get(k, 0.0) + v
                cost.bytes += _fusion_boundary_bytes(
                    comps.get(called.group(1), []), rb)
            else:
                cost.bytes += rb + ob
        elif ins.op in ("call", "conditional", "async-start"):
            for target in re.findall(
                    r"(?:to_apply|calls|branch_computations=\{)[=%]*"
                    r"([\w\.\-]+)", ins.attrs):
                cost.add(_cost_of(target, comps, memo))
            cost.bytes += rb + ob
        elif ins.op in _COLLECTIVES:
            g = _group_size(ins.attrs)
            rb_wire = rb
            # XLA:CPU promotes bf16 all-reduce accumulation to f32
            # ("to_apply=%add.N.clone_promoted"); on the TPU target the
            # wire stays bf16 — price it at its true width.
            if "promoted" in ins.attrs:
                rb_wire = rb / 2
            wire = _collective_wire_bytes(ins.op, rb_wire, g)
            cost.collective_bytes[ins.op] = \
                cost.collective_bytes.get(ins.op, 0.0) + wire
            cost.collective_count += 1
            cost.bytes += rb + ob
        elif ins.op == "dot":
            cost.flops += _dot_flops(ins, types)
            cost.bytes += rb + ob
        elif ins.op == "convolution":
            cost.flops += _conv_flops(ins, types)
            cost.bytes += rb + ob
        elif ins.op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all", "reshape"):
            pass                                    # free / aliasing
        elif ins.op in ("dynamic-slice", "slice", "gather"):
            # traffic = the touched region, NOT the sliced buffer
            cost.bytes += 2 * rb
        elif ins.op == "dynamic-update-slice":
            upd = _shape_bytes(types.get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else rb
            cost.bytes += 2 * upd                   # read+write region; aliased
        elif ins.op == "scatter":
            upd = _shape_bytes(types.get(ins.operands[2], "")) \
                if len(ins.operands) > 2 else rb
            cost.bytes += 3 * upd
        elif ins.op in ("broadcast", "iota"):
            cost.bytes += rb
        elif ins.op in ("concatenate", "pad"):
            cost.bytes += 2 * rb
        else:
            if ins.op in _ELEMENTWISE:
                elems = 1
                for d in _shape_dims(ins.type_str):
                    elems *= d
                cost.flops += elems
            cost.bytes += rb + ob
    memo[comp_name] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    """Per-device cost of a post-SPMD HLO module (see module docstring)."""
    comps = parse_computations(text)
    # cost every computation reachable from ENTRY only
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    entry_name = [k for k, v in comps.items()
                  if v is comps["__entry__"] and k != "__entry__"]
    memo: dict[str, HloCost] = {}
    total = HloCost()
    total.add(_cost_of(entry_name[0], comps, memo))
    return total

"""Checkpoint store: per-leaf .npy shards + JSON manifest, async + atomic.

Fault-tolerance properties (DESIGN.md §5):

* **atomic**: writes go to ``step_N.tmp/`` and are renamed to ``step_N/``
  only after the manifest (with per-leaf byte sizes) is fsynced — a
  preempted writer can never leave a half-checkpoint that restore will
  pick up.
* **async**: device->host transfer happens on the caller thread (cheap),
  file IO on a background thread; ``wait_for_saves()`` joins at exit.
* **elastic**: the manifest stores logical shapes only. ``restore`` takes
  an optional pytree of ``NamedSharding`` for the *current* mesh and
  ``device_put``s each leaf accordingly — a job restarted on a different
  topology (e.g. 256 -> 512 chips) reshards transparently.
* **multi-host**: each process writes only leaves it owns under
  ``proc_{k}``; here (single-process container) that is proc_0. Layout is
  forward-compatible with per-shard writes.
"""

from __future__ import annotations

import json
import os
import re
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "wait_for_saves"]

_PENDING: list[threading.Thread] = []


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        named[key] = leaf
    return named, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, async_: bool = True):
    """Save a pytree at ``ckpt_dir/step_{step}``; returns immediately when
    async (device->host copy is synchronous, IO is not)."""
    named, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in named.items()}

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(os.path.join(tmp, "proc_0"), exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for k, v in host.items():
            fn = k.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, "proc_0", fn), v)
            manifest["leaves"][k] = {"file": f"proc_0/{fn}",
                                     "shape": list(v.shape),
                                     "dtype": str(v.dtype),
                                     "nbytes": int(v.nbytes)}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        _write()


def wait_for_saves():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching pytree of Sharding — leaves are
    device_put with it (elastic resharding onto the current mesh).
    """
    base = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    named_t, treedef = _flatten(target_tree)
    named_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for k, tgt in named_t.items():
        meta = manifest["leaves"][k]
        arr = np.load(os.path.join(base, meta["file"]))
        if arr.dtype.kind == "V":
            # ml_dtypes (bfloat16, fp8...) roundtrip .npy as raw void —
            # reinterpret via the manifest's logical dtype
            import ml_dtypes  # noqa: F401
            arr = arr.view(np.dtype(meta["dtype"]))
        expect = tuple(np.shape(tgt))
        if tuple(arr.shape) != expect:
            raise ValueError(f"checkpoint leaf {k}: shape {arr.shape} != "
                             f"target {expect}")
        arr = arr.astype(np.dtype(jax.numpy.asarray(tgt).dtype))
        if k in named_s and named_s[k] is not None:
            out[k] = jax.device_put(arr, named_s[k])
        else:
            out[k] = jax.numpy.asarray(arr)
    leaves, _ = jax.tree_util.tree_flatten_with_path(target_tree)
    ordered = [out["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, ordered)

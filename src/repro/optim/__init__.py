"""Optimizers + schedules (sharded states, large-scale posture)."""

from .adamw import adamw_init, adamw_update, opt_state_specs
from .schedules import constant_lr, warmup_cosine
from .clip import clip_by_global_norm, global_norm

__all__ = ["adamw_init", "adamw_update", "opt_state_specs",
           "warmup_cosine", "constant_lr", "clip_by_global_norm",
           "global_norm"]

"""AdamW with shard-aligned state and configurable state dtype.

State m/v inherit each parameter's PartitionSpec (ZeRO-style: they live
sharded exactly like the FSDP'd params — no replicated optimizer memory).
``state_dtype=bfloat16`` halves optimizer HBM for the 398B config
(DESIGN.md §5); the update math always runs f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "opt_state_specs"]


def adamw_init(params, state_dtype: str = "float32") -> dict:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs) -> dict:
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs, "v": param_specs, "count": P()}


def adamw_update(grads, opt_state, params, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """Returns (new_params, new_opt_state). lr may be a traced scalar."""
    count = opt_state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0   # no decay on norms
        newp = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}

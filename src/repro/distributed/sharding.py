"""Logical-axis sharding: model code names axes, the mesh maps them.

Model code annotates activations with *logical* axes ("batch", "model",
"expert", "seq"), and the active :class:`MeshRules` — installed by the
launcher for the production mesh, absent in single-device tests — resolves
them to physical mesh axes:

    batch  -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
    model  -> ("model",)          tensor parallel
    expert -> ("model",)          expert parallel shares the TP axis
    seq    -> ("data",)           sequence/context parallel (long_500k)
    fsdp   -> ("data",)           parameter/optimizer ZeRO axis

With no rules installed every constraint is the identity, so the same
model code runs unsharded on one CPU device (smoke tests) and fully
sharded on 512 chips (dry-run) without modification.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["MeshRules", "mesh_rules", "current_rules", "constrain",
           "constrain_tree", "logical_to_spec", "named_sharding",
           "serving_mapping", "fit_spec", "shard_tree"]


@dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    mapping: dict = field(default_factory=lambda: {
        "batch": ("data",),
        "fsdp": ("data",),
        "seq": ("data",),
        "model": ("model",),
        "expert": ("model",),
    })

    def resolve(self, logical) -> P:
        parts = []
        for ax in logical:
            if ax is None:
                parts.append(None)
            else:
                phys = self.mapping.get(ax, ())
                phys = tuple(a for a in phys if a in self.mesh.axis_names)
                if len(phys) == 0:
                    parts.append(None)
                elif len(phys) == 1:
                    parts.append(phys[0])
                else:
                    parts.append(phys)
        return P(*parts)


_ACTIVE: list[MeshRules] = []


@contextlib.contextmanager
def mesh_rules(rules: MeshRules):
    _ACTIVE.append(rules)
    try:
        with rules.mesh:
            yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> MeshRules | None:
    return _ACTIVE[-1] if _ACTIVE else None


def multipod_mapping() -> dict:
    return {
        "batch": ("pod", "data"),
        "fsdp": ("data",),
        "zero": ("pod", "data"),
        "seq": ("data",),
        "model": ("model",),
        "expert": ("model",),
    }


def serving_mapping() -> dict:
    """Logical->physical mapping for the tensor-parallel serving mesh
    (launch/mesh.make_serving_mesh).  Decode is weight-traffic-bound, so
    only "model"/"expert" carry real parallelism (weights stay resident,
    sharded over output channels / experts); "batch" takes the slot axis
    when a data dimension exists, and the training-only axes ("fsdp",
    "seq") resolve to nothing — the serving mesh has no ZeRO/context
    parallelism."""
    return {
        "batch": ("data",),
        "model": ("model",),
        "expert": ("model",),
        "fsdp": (),
        "seq": (),
    }


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes a concrete array can't satisfy on this mesh.

    Per dimension, an axis is kept only if it names mesh axes whose
    total size evenly divides that dimension (e.g. a 2-KV-head pool on a
    4-way "model" axis falls back to replicated for that dim).  This is
    what keeps the host-side engine device-count-agnostic: the same spec
    tree serves any mesh, degrading per-leaf instead of erroring.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            parts.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in names:
            total *= sizes.get(a, 1)
        ok = all(a in sizes for a in names) and shape[i] % total == 0
        parts.append(ax if ok else None)
    return P(*parts)


def shard_tree(tree, spec_tree, rules: MeshRules, logical: bool = False):
    """``device_put`` a pytree of arrays onto ``rules.mesh``.

    ``spec_tree`` mirrors ``tree`` with either ``PartitionSpec`` leaves
    (``logical=False`` — the param_specs convention) or logical-axis
    tuples resolved through ``rules`` (``logical=True`` — the
    cache_specs convention).  Every spec is passed through
    :func:`fit_spec`, so non-dividing / unknown axes degrade to
    replicated rather than raising.
    """
    def put(x, spec):
        if spec is None:
            spec = P()
        if logical:
            spec = rules.resolve(spec)
        spec = fit_spec(spec, jnp_shape(x), rules.mesh)
        return jax.device_put(x, NamedSharding(rules.mesh, spec))

    def jnp_shape(x):
        return getattr(x, "shape", ())

    is_leaf = (lambda s: s is None or isinstance(s, tuple)) if logical \
        else (lambda s: s is None or isinstance(s, P))
    return jax.tree.map(put, tree, spec_tree, is_leaf=is_leaf)


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Annotate activation sharding by logical axis names (or None)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.resolve(logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def constrain_tree(tree, logical_tree):
    """:func:`constrain` over a pytree of activations.

    ``logical_tree`` mirrors ``tree`` with logical-axis tuples (or None
    = leave that leaf unconstrained) at the leaves — the same convention
    as ``cache_specs``/``paged_cache_specs``.  The chunked paged prefill
    uses this to keep its carried recurrent state on the SAME pins as
    the paged cache's state rows (channel axes over "model"), so the
    chunk-to-chunk carry never round-trips through a resharded float
    reduction and mesh-on prefill stays token-identical to mesh-off.
    Identity when no rules are active.
    """
    if current_rules() is None:
        return tree

    def is_spec_leaf(s):
        return s is None or (isinstance(s, tuple) and
                             all(a is None or isinstance(a, str)
                                 for a in s))

    return jax.tree.map(
        lambda lg, x: x if lg is None else constrain(x, *lg),
        logical_tree, tree, is_leaf=is_spec_leaf)


def logical_to_spec(logical) -> P:
    """Resolve a logical tuple to a PartitionSpec under the active rules
    (identity P() when unsharded)."""
    rules = current_rules()
    if rules is None:
        return P()
    return rules.resolve(logical)


def named_sharding(logical) -> NamedSharding | None:
    rules = current_rules()
    if rules is None:
        return None
    return NamedSharding(rules.mesh, rules.resolve(logical))

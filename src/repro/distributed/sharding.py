"""Logical-axis sharding: model code names axes, the mesh maps them.

Model code annotates activations with *logical* axes ("batch", "model",
"expert", "seq"), and the active :class:`MeshRules` — installed by the
launcher for the production mesh, absent in single-device tests — resolves
them to physical mesh axes:

    batch  -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
    model  -> ("model",)          tensor parallel
    expert -> ("model",)          expert parallel shares the TP axis
    seq    -> ("data",)           sequence/context parallel (long_500k)
    fsdp   -> ("data",)           parameter/optimizer ZeRO axis

With no rules installed every constraint is the identity, so the same
model code runs unsharded on one CPU device (smoke tests) and fully
sharded on 512 chips (dry-run) without modification.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["MeshRules", "mesh_rules", "current_rules", "constrain",
           "logical_to_spec", "named_sharding"]


@dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    mapping: dict = field(default_factory=lambda: {
        "batch": ("data",),
        "fsdp": ("data",),
        "seq": ("data",),
        "model": ("model",),
        "expert": ("model",),
    })

    def resolve(self, logical) -> P:
        parts = []
        for ax in logical:
            if ax is None:
                parts.append(None)
            else:
                phys = self.mapping.get(ax, ())
                phys = tuple(a for a in phys if a in self.mesh.axis_names)
                if len(phys) == 0:
                    parts.append(None)
                elif len(phys) == 1:
                    parts.append(phys[0])
                else:
                    parts.append(phys)
        return P(*parts)


_ACTIVE: list[MeshRules] = []


@contextlib.contextmanager
def mesh_rules(rules: MeshRules):
    _ACTIVE.append(rules)
    try:
        with rules.mesh:
            yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> MeshRules | None:
    return _ACTIVE[-1] if _ACTIVE else None


def multipod_mapping() -> dict:
    return {
        "batch": ("pod", "data"),
        "fsdp": ("data",),
        "zero": ("pod", "data"),
        "seq": ("data",),
        "model": ("model",),
        "expert": ("model",),
    }


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Annotate activation sharding by logical axis names (or None)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.resolve(logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def logical_to_spec(logical) -> P:
    """Resolve a logical tuple to a PartitionSpec under the active rules
    (identity P() when unsharded)."""
    rules = current_rules()
    if rules is None:
        return P()
    return rules.resolve(logical)


def named_sharding(logical) -> NamedSharding | None:
    rules = current_rules()
    if rules is None:
        return None
    return NamedSharding(rules.mesh, rules.resolve(logical))

"""Gradient compression for cross-pod reduction (distributed-optimization).

int8 error-feedback compression: gradients are quantized to int8 with a
per-tensor scale before the data-parallel all-reduce, and the quantization
residual is fed back into the next step (Seide et al. / EF-SGD family —
unbiased in the long run, 4x less reduce traffic in bf16 terms, 2x vs
fp16).  Exposed two ways:

* :func:`compress_decompress` — the pure quantize/dequantize pair with
  error feedback, used inside a standard pjit train step (GSPMD still
  performs the reduction, on the *compressed-then-restored* values: the
  numerics of compression without manual collectives).
* :func:`compressed_psum` — explicit shard_map collective: quantize,
  ``psum`` the int32, dequantize; for the launcher's ``--grad-compress
  collective`` mode where the wire traffic itself must shrink.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["compress_decompress", "compressed_psum", "init_error_state"]


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32)
                        if p.ndim >= 2 else None, params)


def _quant_int8(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, error_state):
    """Error-feedback int8 round-trip. Returns (grads', new_error_state)."""
    def one(g, e):
        if e is None or g.ndim < 2:
            return g, e
        gf = g.astype(jnp.float32) + e
        q, scale = _quant_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq
    pairs = jax.tree.map(one, grads, error_state,
                         is_leaf=lambda x: x is None)
    g2 = jax.tree.map(lambda t: t[0], pairs,
                      is_leaf=lambda t: isinstance(t, tuple))
    e2 = jax.tree.map(lambda t: t[1], pairs,
                      is_leaf=lambda t: isinstance(t, tuple))
    return g2, e2


def compressed_psum(g: jax.Array, axis_name: str):
    """Explicit compressed all-reduce for use under shard_map: int8 on the
    wire, int32 accumulate (bit-exact associativity — reduction order
    independent, unlike float psum)."""
    q, scale = _quant_int8(g.astype(jnp.float32))
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    max_scale = jax.lax.pmax(scale, axis_name)
    # conservative shared scale: rescale local contributions
    return total.astype(jnp.float32) * max_scale

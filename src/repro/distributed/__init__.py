"""Distribution substrate: mesh construction, sharding rules, collectives."""

from . import sharding

__all__ = ["sharding"]

"""repro: end-to-end stochastic-computing acceleration framework in JAX.

Reproduction + TPU adaptation of "Efficient yet Accurate End-to-End SC
Accelerator Design" (Li et al., 2024). See DESIGN.md.
"""

__version__ = "1.0.0"

"""Dense FFN (gated SwiGLU/GeGLU or plain squared-ReLU) — SC-quantized.

nemotron's squared-ReLU is the paper's best case: accumulate -> monotone
activation is *exactly* the BSN+SI pattern (DESIGN.md §4).  Gated variants
quantize the three projections; the gate multiply stays in the residual
(high-precision) domain, mirroring §III's split.
"""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from .common import ACT_FNS, DATA, MODEL, dense_apply, dense_init, dense_spec

__all__ = ["ffn_init", "ffn_spec", "ffn_apply"]


def ffn_init(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    import jax.numpy as jnp
    d_ff = d_ff or cfg.d_ff
    q = cfg.quant
    dt = jnp.dtype(cfg.dtype)
    if cfg.ffn_gated:
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w_gate": dense_init(k1, cfg.d_model, d_ff, q, dtype=dt),
                "w_up": dense_init(k2, cfg.d_model, d_ff, q, dtype=dt),
                "w_down": dense_init(k3, d_ff, cfg.d_model, q, dtype=dt)}
    k1, k2 = jax.random.split(key, 2)
    return {"w_up": dense_init(k1, cfg.d_model, d_ff, q, dtype=dt),
            "w_down": dense_init(k2, d_ff, cfg.d_model, q, dtype=dt)}


def ffn_spec(cfg: ModelConfig, serving: bool = False) -> dict:
    """Training: w_up/w_gate column-, w_down row-parallel.  Serving:
    all three column-parallel (output over "model", contraction local) —
    same rationale as ``attention.attn_spec``: the per-output-channel
    BSN accumulator must not be split across devices, and decode wants
    weights resident with only activations moving."""
    q = cfg.quant
    if serving:
        s = {"w_up": dense_spec(None, MODEL, q),
             "w_down": dense_spec(None, MODEL, q)}
        if cfg.ffn_gated:
            s["w_gate"] = dense_spec(None, MODEL, q)
        return s
    s = {"w_up": dense_spec(DATA, MODEL, q),
         "w_down": dense_spec(MODEL, DATA, q)}
    if cfg.ffn_gated:
        s["w_gate"] = dense_spec(DATA, MODEL, q)
    return s


def ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = ACT_FNS[cfg.ffn_act]
    if cfg.ffn_gated:
        h = act(dense_apply(p["w_gate"], x, cfg.quant)) \
            * dense_apply(p["w_up"], x, cfg.quant)
    else:
        h = act(dense_apply(p["w_up"], x, cfg.quant))
    return dense_apply(p["w_down"], h, cfg.quant)

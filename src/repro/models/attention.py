"""GQA attention: flash-style (triangle-exact) training/prefill + cached decode.

The chunked path scans over exactly the lower-triangle (q-block, kv-block)
pairs with an online-softmax carry, so (a) no (S, S) logits tensor ever
materializes (required for the 32k prefill cells) and (b) the HLO FLOPs
match the true causal work — no 2x masked overcompute polluting the
roofline (DESIGN.md §6).

Decode attends one query against the full KV cache directly; with the
cache sequence-sharded (long_500k) GSPMD lowers the softmax into the
flash-decoding LSE-merge pattern automatically.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.kv_quant import kv_format_of, kv_quant
from repro.distributed.sharding import constrain, current_rules
from repro.kernels import dispatch as kernel_dispatch
from repro.kernels import ref as kernel_ref
from .common import (DATA, MODEL, apply_rope, dense_apply, dense_init,
                     dense_spec, norm_apply, norm_init, norm_spec)

__all__ = ["attn_init", "attn_spec", "attn_train", "attn_decode",
           "attn_decode_paged", "attn_prefill_paged", "flash_attention"]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key: jax.Array, cfg: ModelConfig) -> dict:
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    q = cfg.quant
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, hq * dh, q, dtype=dt),
        "wk": dense_init(ks[1], cfg.d_model, hkv * dh, q, dtype=dt),
        "wv": dense_init(ks[2], cfg.d_model, hkv * dh, q, dtype=dt),
        "wo": dense_init(ks[3], hq * dh, cfg.d_model, q, dtype=dt),
    }
    if getattr(cfg, "qk_norm", False):
        p["q_norm"] = norm_init(dh, "rmsnorm")
        p["k_norm"] = norm_init(dh, "rmsnorm")
    return p


def attn_spec(cfg: ModelConfig, serving: bool = False) -> dict:
    """Training: Megatron TP (wq/wk/wv column-, wo row-parallel — the
    all-reduce amortizes over the token batch).  Serving: EVERY
    projection is column-parallel (output channels over "model", no
    contraction dim sharded).  Decode is weight-resident by design, and
    the SC datapaths make contraction sharding wrong, not just slow: the
    approximate BSN adder (``sc_int_approx``) is a nonlinear per-output-
    channel accumulator, so splitting its K inputs across chips changes
    the answer — whole adders must live on one device.  Column-parallel
    keeps each channel's accumulation device-local (mesh-on output is
    token-identical to mesh-off) at the cost of all-gathering the (tiny)
    decode activations instead of all-reducing partials."""
    q = cfg.quant
    in_ax = None if serving else DATA
    s = {
        "wq": dense_spec(in_ax, MODEL, q),
        "wk": dense_spec(in_ax, MODEL, q),
        "wv": dense_spec(in_ax, MODEL, q),
        "wo": dense_spec(None, MODEL, q) if serving
        else dense_spec(MODEL, DATA, q),
    }
    if getattr(cfg, "qk_norm", False):
        s["q_norm"] = norm_spec("rmsnorm")
        s["k_norm"] = norm_spec("rmsnorm")
    return s


# ---------------------------------------------------------------------------
# flash attention (pair-list scan, exact triangle FLOPs)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool, chunk: int) -> jax.Array:
    """q: (B,S,Hkv,G,Dh); k,v: (B,S,Hkv,Dh) -> (B,S,Hkv,G,Dh).

    Scans (i, j) block pairs — j<=i for causal, all for bidirectional —
    carrying (m, l, acc) online-softmax state per q block; each row i is
    flushed into the output buffer at its final pair.
    """
    B, S, H, G, D = q.shape
    c = min(chunk, S)
    if S % c:
        c = math.gcd(S, c)
    n = S // c
    scale = 1.0 / math.sqrt(D)
    qb = (q * scale).astype(jnp.float32).reshape(B, n, c, H, G, D)
    kb = k.astype(jnp.float32).reshape(B, n, c, H, D)
    vb = v.astype(jnp.float32).reshape(B, n, c, H, D)

    if causal:
        pairs = [(i, j) for i in range(n) for j in range(i + 1)]
    else:
        pairs = [(i, j) for i in range(n) for j in range(n)]
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    neg = -1e30
    m0 = jnp.full((B, H, G, c), neg, jnp.float32)
    l0 = jnp.zeros((B, H, G, c), jnp.float32)
    a0 = jnp.zeros((B, H, G, c, D), jnp.float32)
    tri = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])  # (cq, ck)

    # The step is checkpointed: its backward recomputes the (c, c) logits
    # tile instead of saving one per pair (the stacked residual would be
    # n_pairs x tile — 10s of GB/device at 32k — the flash point exactly).
    @jax.checkpoint
    def step(carry, ij):
        m, l, acc = carry
        i, j = ij
        fresh = (j == 0)
        m = jnp.where(fresh, neg, m)
        l = jnp.where(fresh, 0.0, l)
        acc = jnp.where(fresh, 0.0, acc)
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj)
        if causal:  # mask only the diagonal block's upper triangle
            diag = (i == j)
            logits = jnp.where(jnp.logical_or(~diag, tri), logits, neg)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, blk_max)
        p = jnp.exp(logits - new_m[..., None])
        corr = jnp.exp(m - new_m)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vj)
        # emit this pair's normalized tile; the post-scan gather keeps only
        # each row's final (diagonal / last-column) emission
        o_blk = (acc / jnp.maximum(l[..., None], 1e-30))
        o_blk = jnp.moveaxis(o_blk, -2, 1).astype(q.dtype)    # (B,c,H,G,D)
        return (new_m, l, acc), o_blk

    (_, _, _), ys = jax.lax.scan(step, (m0, l0, a0), (pi, pj))
    if causal:  # row i finalized at its diagonal pair
        final_idx = jnp.asarray([i * (i + 1) // 2 + i for i in range(n)])
    else:
        final_idx = jnp.asarray([(i + 1) * n - 1 for i in range(n)])
    out = jnp.moveaxis(ys[final_idx], 0, 1)                   # (B,n,c,H,G,D)
    return out.reshape(B, S, H, G, D)


# ---------------------------------------------------------------------------
# full layers
# ---------------------------------------------------------------------------

def _project_qkv(p: dict, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array):
    B, S, _ = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense_apply(p["wq"], x, cfg.quant).reshape(B, S, hq, dh)
    k = dense_apply(p["wk"], x, cfg.quant).reshape(B, S, hkv, dh)
    v = dense_apply(p["wv"], x, cfg.quant).reshape(B, S, hkv, dh)
    if "q_norm" in p:
        q = norm_apply(p["q_norm"], q, "rmsnorm")
        k = norm_apply(p["k_norm"], k, "rmsnorm")
    q = apply_rope(q, positions, dh, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, positions, dh, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


def attn_train(p: dict, x: jax.Array, cfg: ModelConfig,
               positions: jax.Array):
    """Full-sequence attention (train / prefill). Returns (y, (k, v))."""
    B, S, _ = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    q, k, v = _project_qkv(p, x, cfg, positions)
    qg = q.reshape(B, S, hkv, g, dh)
    o = flash_attention(qg, k, v, cfg.causal, cfg.attn_q_chunk)
    o = o.reshape(B, S, hq * dh)
    y = dense_apply(p["wo"], o, cfg.quant)
    return y, (k, v)


def _decode_kv_time_axis(cfg: ModelConfig, batch: int) -> str | None:
    """Which logical axis carries the KV cache's time dimension — must
    mirror launch/dryrun.py's cache_specs choice so the attention einsums
    are constrained consistently with the cache's input sharding."""
    rules = current_rules()
    if rules is None:
        return None
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
    if batch == 1:
        return "seq"                              # long_500k context shard
    if cfg.n_kv_heads % sizes.get("model", 1) != 0:
        return "model"                            # flash-decoding split-KV
    return None                                   # heads carry "model"


def attn_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array):
    """One-token decode. x: (B, 1, D); caches: (B, T, Hkv, Dh); pos scalar.

    Returns (y (B,1,D), new k_cache, new v_cache).  When the cache's time
    axis is sharded ("model" for small-KV-head archs, "seq" for long
    contexts), the logits/output einsums are constrained to keep the
    partials sharded over time and merge via psum — flash-decoding —
    instead of letting GSPMD all-gather the whole cache (54 GB/step for
    qwen3 decode_32k; see EXPERIMENTS.md §Perf).
    """
    B, _, _ = x.shape
    T = k_cache.shape[1]
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    qg = q.reshape(B, hkv, g, dh)
    t_axis = _decode_kv_time_axis(cfg, B)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / math.sqrt(dh)
    if t_axis is not None:
        logits = constrain(logits, "batch" if B > 1 else None,
                           None, None, t_axis)
    valid = (jnp.arange(T) <= pos)[None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", w, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, hq * dh).astype(x.dtype)
    y = dense_apply(p["wo"], o, cfg.quant)
    return y, k_cache, v_cache


# ---------------------------------------------------------------------------
# paged KV cache (ServeEngine v2)
# ---------------------------------------------------------------------------
#
# The serving engine stores KV in a flat pool of fixed-size pages shared
# by every request (serving/paging.py owns the allocation); the two
# functions below scatter the new K/V into the pools and attend over
# that layout.  Per slot ``s`` position ``t`` lives at physical page
# ``page_tables[s, t // page]`` offset ``t % page``.  Page-table padding
# points at the reserved trash page (writes land there harmlessly; reads
# are masked by ``lengths``), so no cross-request leakage is possible by
# construction.
#
# The attention math itself routes through kernels/dispatch.py: the
# flash-decoding Pallas kernel (kernels/paged_attention.py) reads pages
# directly through the table, with the XLA gather/scatter path
# (kernels/ref.py) as the reference oracle.  Under active mesh rules the
# constrained reference always serves: the kernel is a single-device
# program, and the serving contract keeps KV heads device-local over
# "model", so the per-device work IS the unsharded math — mesh-on is
# token-identical to the kernel path (tests/test_sharded_serving.py).
#
# Both functions take the engine's pool dict (``pools``): always
# ``k_pages``/``v_pages``/``page_tables``, plus the parallel
# ``k_scale``/``v_scale`` (+ sc ``k_resid``/``v_resid``) leaves when the
# cache is compressed (core/kv_quant.py — the dict's keys ARE the
# format).  New K/V quantize on scatter: only the just-written positions
# are encoded, existing pages are never touched, so batched and
# sequential serving stay bit-identical within a format.

_AUX_KEYS = ("k_scale", "v_scale", "k_resid", "v_resid")


def _pin_pool(a: jax.Array) -> jax.Array:
    """Pools stay KV-head-sharded across steps (weights-resident layout);
    scatter indices are replicated, so the update is device-local.  Works
    for KV/resid pools (N, page, Hkv, Dh) and scale pools (N, page, Hkv)."""
    return constrain(a, None, None, "model", *(None,) * (a.ndim - 3))


def _scatter_pools(pools: dict, fmt: str, k_new: jax.Array,
                   v_new: jax.Array, put) -> dict:
    """Quantize-on-scatter: encode the new K/V rows and write every pool
    leaf through ``put(pool, values)`` (same indices for codes, scales
    and residuals — the pools are position-parallel)."""
    out = {}
    for name, val in (("k", k_new), ("v", v_new)):
        qd = kv_quant(val, fmt)
        out[f"{name}_pages"] = _pin_pool(put(pools[f"{name}_pages"],
                                             qd["q"]))
        if "scale" in qd:
            out[f"{name}_scale"] = _pin_pool(put(pools[f"{name}_scale"],
                                                 qd["scale"]))
        if "resid" in qd:
            out[f"{name}_resid"] = _pin_pool(put(pools[f"{name}_resid"],
                                                 qd["resid"]))
    return out


def _kv_aux(pools: dict) -> dict:
    return {k: pools[k] for k in _AUX_KEYS if k in pools}


def attn_decode_paged(p: dict, x: jax.Array, cfg: ModelConfig,
                      pools: dict, lengths: jax.Array):
    """Batched one-token decode over the paged KV cache.

    x: (S, 1, D) — one new token per active slot; ``pools`` holds the
    (N, page, Hkv, Dh) KV pools + (S, maxp) int32 ``page_tables`` (+ any
    scale/resid leaves); lengths: (S,) int32 tokens already in the cache
    (== the new token's position).  Returns (y (S, 1, D), new_pools) —
    the updated pool leaves, page_tables excluded.
    """
    page_tables = pools["page_tables"]
    page = pools["k_pages"].shape[1]
    fmt = kv_format_of(pools)
    S = x.shape[0]
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    positions = lengths[:, None]                            # (S, 1)
    q, k, v = _project_qkv(p, x, cfg, positions)
    # scatter the new K/V row: one (phys_page, offset) per slot.  Distinct
    # active slots own distinct pages, so indices never collide; padded
    # lanes all hit the trash page, where last-writer-wins is fine.
    phys = jnp.take_along_axis(page_tables, (lengths // page)[:, None],
                               axis=1)[:, 0]
    off = lengths % page
    new_pools = _scatter_pools(
        pools, fmt, k[:, 0], v[:, 0],
        lambda pool, val: pool.at[phys, off].set(val.astype(pool.dtype)))

    qg = q.reshape(S, hkv, g, dh)
    aux = _kv_aux(new_pools)
    if current_rules() is not None:
        # mesh path: the constrained XLA reference (KV-head axis stays
        # "model"-sharded through the logits; see module comment above)
        o = kernel_ref.paged_attn_decode_ref(
            qg, new_pools["k_pages"], new_pools["v_pages"], page_tables,
            lengths, kv_format=fmt, kv_aux=aux,
            pin_logits=lambda lg: constrain(lg, None, "model", None, None))
    else:
        o = kernel_dispatch.paged_attn_decode(
            qg, new_pools["k_pages"], new_pools["v_pages"], page_tables,
            lengths, kv_format=fmt, kv_aux=aux)
    o = o.reshape(S, 1, hq * dh).astype(x.dtype)
    # gather the head-sharded context BEFORE wo: the serving wo is
    # column-parallel, so its hq*dh contraction must be device-local
    # (never partial-summed — see attn_spec's serving rationale)
    o = constrain(o, None, None, None)
    y = dense_apply(p["wo"], o, cfg.quant)
    return y, new_pools


def attn_verify_paged(p: dict, x: jax.Array, cfg: ModelConfig,
                      pools: dict, lengths: jax.Array):
    """Batched multi-token speculative-verify over the paged KV cache.

    x: (S, T, D) — the verify window per slot (last committed token +
    the T-1 draft tokens), token t sitting at cache position
    ``lengths + t``.  Scatters all T K/V rows (overwriting whatever the
    draft pass left there), then scores all T queries in ONE parallel
    attention pass, each under its own causal horizon — so the whole
    window costs one step of projections/attention instead of T decode
    steps.  Returns (y (S, T, D), new_pools).

    A draft window can straddle a page boundary; the per-position
    (phys, off) scatter below handles that, and distinct lanes own
    distinct pages so indices never collide (padded lanes hit the trash
    page).  There is no Pallas verify kernel yet — this routes through
    the XLA reference unconditionally (see ROADMAP), with the mesh
    path's logit pin matching decode.
    """
    page_tables = pools["page_tables"]
    page = pools["k_pages"].shape[1]
    fmt = kv_format_of(pools)
    S, T = x.shape[0], x.shape[1]
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    positions = lengths[:, None] + jnp.arange(T)[None, :]   # (S, T)
    q, k, v = _project_qkv(p, x, cfg, positions)
    phys = jnp.take_along_axis(page_tables, positions // page, axis=1)
    off = positions % page                                  # (S, T)
    new_pools = _scatter_pools(
        pools, fmt, k, v,
        lambda pool, val: pool.at[phys, off].set(val.astype(pool.dtype)))

    qg = q.reshape(S, T, hkv, g, dh)
    aux = _kv_aux(new_pools)
    if current_rules() is not None:
        o = kernel_ref.paged_attn_verify_ref(
            qg, new_pools["k_pages"], new_pools["v_pages"], page_tables,
            lengths, kv_format=fmt, kv_aux=aux,
            pin_logits=lambda lg: constrain(lg, None, "model",
                                            None, None, None))
    else:
        o = kernel_ref.paged_attn_verify_ref(
            qg, new_pools["k_pages"], new_pools["v_pages"], page_tables,
            lengths, kv_format=fmt, kv_aux=aux)
    o = o.reshape(S, T, hq * dh).astype(x.dtype)
    o = constrain(o, None, None, None)
    y = dense_apply(p["wo"], o, cfg.quant)
    return y, new_pools


def attn_prefill_paged(p: dict, x: jax.Array, cfg: ModelConfig,
                       pools: dict, start: int):
    """One prefill chunk written straight into the decode page layout.

    x: (G, C, D) — chunk ``[start, start+C)`` of each request in the
    admission group, with ``C`` a multiple of the page size and ``start``
    chunk-aligned (static).  K/V of the chunk are scattered as whole
    pages (quantized on scatter for compressed ``pools``), then the
    chunk's queries attend over every page written so far (positions
    < start + C) under the causal mask — the online equivalent of flash
    prefill, sharing the decode cache layout so no re-layout pass sits
    between prefill and decode.

    Returns (y (G, C, D), new_pools).
    """
    page_tables = pools["page_tables"]
    page = pools["k_pages"].shape[1]
    fmt = kv_format_of(pools)
    G, C, _ = x.shape
    assert C % page == 0 and start % page == 0, (C, page, start)
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    positions = start + jnp.broadcast_to(
        jnp.arange(C, dtype=jnp.int32), (G, C))
    q, k, v = _project_qkv(p, x, cfg, positions)            # (G,C,H,Dh)

    # whole-page scatter: chunk pages j cover positions start + j*page
    p0 = start // page
    npg = C // page
    phys = page_tables[:, p0:p0 + npg].reshape(-1)          # (G*npg,)
    new_pools = _scatter_pools(
        pools, fmt, k, v,
        lambda pool, val: pool.at[phys].set(
            val.reshape(G * npg, page, *val.shape[2:]).astype(pool.dtype)))

    qg = q.reshape(G, C, hkv, g, dh)
    aux = _kv_aux(new_pools)
    if current_rules() is not None:
        o = kernel_ref.paged_attn_prefill_ref(
            qg, new_pools["k_pages"], new_pools["v_pages"], page_tables,
            start, kv_format=fmt, kv_aux=aux,
            pin_logits=lambda lg: constrain(lg, None, "model",
                                            None, None, None))
    else:
        o = kernel_dispatch.paged_attn_prefill(
            qg, new_pools["k_pages"], new_pools["v_pages"], page_tables,
            start, kv_format=fmt, kv_aux=aux)
    o = o.reshape(G, C, hq * dh).astype(x.dtype)
    o = constrain(o, None, None, None)      # see attn_decode_paged
    y = dense_apply(p["wo"], o, cfg.quant)
    return y, new_pools

"""Unified LM: dense / MoE / SSM / hybrid / encoder / VLM-backbone.

The architecture is a *period* of heterogeneous layers (cfg.period)
repeated ``cfg.n_periods`` times; parameters are stacked over the period
axis and the forward pass is a single ``lax.scan`` (compile time stays
flat in depth — required for the 94-layer qwen3 dry-run), with per-period
``jax.checkpoint`` remat.

High-precision-residual fusion (paper §III): in ``sc_qat`` mode the
datapath matmuls run at ``act_bsl`` while the residual stream re-quantizes
at ``resid_bsl`` after every add (learned scales ``alpha_r*``), the LM
analogue of Fig 6(b).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.kv_quant import check_kv_format
from repro.core.sc_layers import sc_residual_quant
from repro.distributed.sharding import constrain, constrain_tree

from . import attention, ffn, mamba, moe, rwkv6
from .common import (DATA, MODEL, add_leading_none, dense_apply, dense_init,
                     dense_spec, embed_init, embed_spec, norm_apply,
                     norm_init, norm_spec)

__all__ = ["init_params", "param_specs", "forward", "loss_fn", "init_cache",
           "cache_specs", "paged_cache_specs", "decode_step", "prefill",
           "batch_specs", "make_dummy_batch", "init_paged_cache",
           "paged_decode_step", "paged_prefill", "supports_paged_prefill"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

_MIXER_INIT = {"attn": attention.attn_init, "mamba": mamba.mamba_init,
               "rwkv6": rwkv6.rwkv_tmix_init}
_MIXER_SPEC = {"attn": attention.attn_spec, "mamba": mamba.mamba_spec,
               "rwkv6": rwkv6.rwkv_tmix_spec}


def _ffn_init(key, cfg: ModelConfig, kind: str):
    if kind == "dense":
        return ffn.ffn_init(key, cfg)
    if kind == "moe":
        return moe.moe_init(key, cfg)
    if kind == "rwkv_cmix":
        return rwkv6.rwkv_cmix_init(key, cfg)
    raise ValueError(kind)


def _ffn_spec(cfg: ModelConfig, kind: str, serving: bool = False):
    if kind == "dense":
        return ffn.ffn_spec(cfg, serving=serving)
    if kind == "moe":
        return moe.moe_spec(cfg, serving=serving)
    if kind == "rwkv_cmix":
        return rwkv6.rwkv_cmix_spec(cfg)
    raise ValueError(kind)


def _position_init(key: jax.Array, cfg: ModelConfig, spec: LayerSpec) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"norm1": norm_init(cfg.d_model, cfg.norm),
         "mixer": _MIXER_INIT[spec.mixer](k1, cfg)}
    if spec.ffn != "none":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = _ffn_init(k2, cfg, spec.ffn)
    if cfg.quant.enabled:
        p["alpha_r1"] = jnp.asarray(0.05, jnp.float32)
        p["alpha_r2"] = jnp.asarray(0.05, jnp.float32)
    return p


def _period_init(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, len(cfg.period))
    return {f"p{i}": _position_init(ks[i], cfg, spec)
            for i, spec in enumerate(cfg.period)}


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_per, k_head, k_front = jax.random.split(key, 4)
    params = {"embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                  dtype)}
    period_keys = jax.random.split(k_per, cfg.n_periods)
    params["periods"] = jax.vmap(partial(_period_init, cfg=cfg))(period_keys)
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm)
    params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.padded_vocab,
                                   cfg.quant, dtype=dtype)
    if cfg.frontend == "vision_stub":
        kv1, kv2 = jax.random.split(k_front)
        params["frontend"] = {
            "w1": dense_init(kv1, 1024, cfg.d_model, cfg.quant, dtype=dtype),
            "w2": dense_init(kv2, cfg.d_model, cfg.d_model, cfg.quant,
                             dtype=dtype)}
    elif cfg.frontend == "audio_stub":
        params["frontend"] = {
            "w1": dense_init(k_front, 512, cfg.d_model, cfg.quant,
                             dtype=dtype)}
    return params


def param_specs(cfg: ModelConfig, serving: bool = False) -> dict:
    def mixer_spec(spec: LayerSpec) -> dict:
        if spec.mixer == "attn":
            return attention.attn_spec(cfg, serving=serving)
        return _MIXER_SPEC[spec.mixer](cfg)

    def pos_spec(spec: LayerSpec) -> dict:
        s = {"norm1": norm_spec(cfg.norm),
             "mixer": mixer_spec(spec)}
        if spec.ffn != "none":
            s["norm2"] = norm_spec(cfg.norm)
            s["ffn"] = _ffn_spec(cfg, spec.ffn, serving=serving)
        if cfg.quant.enabled:
            s["alpha_r1"] = P()
            s["alpha_r2"] = P()
        return s

    periods = {f"p{i}": pos_spec(spec) for i, spec in enumerate(cfg.period)}
    specs = {
        "embed": embed_spec(),
        "periods": add_leading_none(periods),
        "final_norm": norm_spec(cfg.norm),
        # serving: vocab column-parallel with the d_model contraction
        # local (same no-split-accumulator rule as attn/ffn specs)
        "lm_head": dense_spec(None if serving else DATA, MODEL, cfg.quant),
    }
    if cfg.frontend == "vision_stub":
        specs["frontend"] = {"w1": dense_spec(None, None, cfg.quant),
                             "w2": dense_spec(None, None, cfg.quant)}
    elif cfg.frontend == "audio_stub":
        specs["frontend"] = {"w1": dense_spec(None, None, cfg.quant)}
    return specs


# ---------------------------------------------------------------------------
# shared layer application
# ---------------------------------------------------------------------------

def _residual_add(x, dx, lp, name, cfg: ModelConfig):
    # dtype-preserving residual quant: an f32 round-trip here would promote
    # the whole backward pass (every TP all-reduce) to f32 — §Perf cell C
    y = x + dx
    if cfg.quant.enabled and cfg.quant.mode == "sc_qat":
        y = sc_residual_quant(y, lp[name], cfg.quant)
    return y


def _verify_scan(fn, x, state):
    """Scan a per-token DECODE mixer over the (S, T, D) verify window.

    Speculative verify must produce bit-identical hidden states to T
    successive decode steps — so rather than trust a batched recurrence
    kernel to reassociate identically, it literally runs the decode-mode
    update once per window token (the recurrent cores are a handful of
    ops; the heavy attention/FFN work around them stays batched over
    the window).  Returns (dx (S, T, D), snaps) where snaps stacks the
    post-token state pytree along a leading T axis — the engine commits
    exactly one snapshot per lane (its accepted-prefix length).
    """
    def body(st, xt):
        dx, st2 = fn(xt[:, None, :], st)
        return st2, (dx[:, 0, :], st2)
    _, (dxs, snaps) = jax.lax.scan(body, state, jnp.moveaxis(x, 0, 1))
    return jnp.moveaxis(dxs, 0, 1), snaps


def _apply_position(lp: dict, spec: LayerSpec, x, cfg: ModelConfig,
                    positions, mode: str, cstate: dict | None, pos):
    """One layer (mixer + ffn). Returns (x, aux, new_cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    centry = {}
    # Paged serving runs under the column-parallel serving specs: every
    # projection output is feature-sharded over "model", so the residual
    # stream is pinned back to replicated after each add.  This is the
    # "all-gather activations" half of the serving layout — and it keeps
    # every norm/quantizer reduction device-local, which is what makes
    # mesh-on decode token-identical to mesh-off (no resharded float
    # reductions).  `constrain` is the identity when no mesh is active.
    paged = (mode == "paged_prefill"
             or (cstate is not None and "page_tables" in cstate))
    def repl(y):
        return constrain(y, None, None, None) if paged else y
    h = norm_apply(lp["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        if mode == "decode" and "k_pages" in (cstate or {}):
            # batched paged decode: pos is the (S,) per-slot length
            # vector; the pool dict's keys carry the kv_format (scale /
            # residual leaves present iff the cache is compressed)
            dx, centry = attention.attn_decode_paged(
                lp["mixer"], h, cfg, cstate, pos)
        elif mode == "paged_prefill":
            dx, centry = attention.attn_prefill_paged(
                lp["mixer"], h, cfg, cstate, cstate["start"])
        elif mode == "verify":
            # speculative verify: all T window queries in one parallel
            # pass, each under its own causal horizon (pos = lengths)
            dx, centry = attention.attn_verify_paged(
                lp["mixer"], h, cfg, cstate, pos)
        elif mode == "decode":
            dx, kc, vc = attention.attn_decode(
                lp["mixer"], h, cfg, cstate["k"], cstate["v"], pos)
            centry = {"k": kc, "v": vc}
        else:
            dx, (k, v) = attention.attn_train(lp["mixer"], h, cfg, positions)
            if mode == "prefill":
                centry = {"k": k, "v": v}
    elif spec.mixer == "mamba":
        # prefill (exact AND chunked-paged) runs the chunk-resumable
        # per-token recurrence: exact prefill is the one-chunk special
        # case (zero state in), so chunked serving prefill is bit-equal
        # to it at every split.  Train keeps the associative scan.
        if mode == "decode":
            dx, centry = mamba.mamba_decode(lp["mixer"], h, cfg, cstate)
        elif mode == "verify":
            dx, centry = _verify_scan(
                lambda xt, st: mamba.mamba_decode(lp["mixer"], xt, cfg, st),
                h, {"h": cstate["h"], "conv": cstate["conv"]})
        elif mode == "paged_prefill":
            dx, centry = mamba.mamba_prefill_chunk(
                lp["mixer"], h, cfg,
                {"h": cstate["h"], "conv": cstate["conv"]},
                valid=cstate["valid"])
        elif mode == "prefill":
            dx, centry = mamba.mamba_prefill_chunk(
                lp["mixer"], h, cfg,
                mamba.mamba_state_init(cfg, h.shape[0], h.dtype))
        else:
            dx, _ = mamba.mamba_train(lp["mixer"], h, cfg)
    elif spec.mixer == "rwkv6":
        if mode == "decode":
            dx, centry = rwkv6.rwkv_tmix_decode(lp["mixer"], h, cfg, cstate)
        elif mode == "verify":
            dx, centry = _verify_scan(
                lambda xt, st: rwkv6.rwkv_tmix_decode(
                    lp["mixer"], xt, cfg, st),
                h, {"s": cstate["s"], "shift": cstate["shift"]})
        elif mode == "paged_prefill":
            dx, centry = rwkv6.rwkv_tmix_prefill_chunk(
                lp["mixer"], h, cfg,
                {"s": cstate["s"], "shift": cstate["shift"]},
                valid=cstate["valid"])
        elif mode == "prefill":
            dx, centry = rwkv6.rwkv_tmix_prefill_chunk(
                lp["mixer"], h, cfg,
                rwkv6.rwkv_state_init(cfg, h.shape[0], h.dtype))
        else:
            dx, _ = rwkv6.rwkv_tmix_train(lp["mixer"], h, cfg)
    else:
        raise ValueError(spec.mixer)
    x = repl(_residual_add(x, repl(dx), lp, "alpha_r1", cfg))

    if spec.ffn != "none":
        h2 = norm_apply(lp["norm2"], x, cfg.norm)
        if spec.ffn == "dense":
            dx2 = ffn.ffn_apply(lp["ffn"], h2, cfg)
        elif spec.ffn == "moe":
            dx2, aux_l = moe.moe_apply(lp["ffn"], h2, cfg)
            aux = aux + aux_l
        elif spec.ffn == "rwkv_cmix":
            if mode == "decode":
                dx2, cshift = rwkv6.rwkv_cmix_decode(
                    lp["ffn"], h2, cfg, cstate["cmix"] if cstate else None)
                centry = dict(centry, cmix=cshift)
            elif mode == "verify":
                dx2, cshift = _verify_scan(
                    lambda xt, st: rwkv6.rwkv_cmix_decode(
                        lp["ffn"], xt, cfg, st),
                    h2, cstate["cmix"])
                centry = dict(centry, cmix=cshift)
            elif mode == "paged_prefill":
                dx2, cshift = rwkv6.rwkv_cmix_prefill_chunk(
                    lp["ffn"], h2, cfg, cstate["cmix"],
                    valid=cstate["valid"])
                centry = dict(centry, cmix=cshift)
            elif mode == "prefill":
                dx2, cshift = rwkv6.rwkv_cmix_prefill_chunk(
                    lp["ffn"], h2, cfg,
                    {"shift": jnp.zeros((h2.shape[0], cfg.d_model),
                                        h2.dtype)})
                centry = dict(centry, cmix=cshift)
            else:
                dx2, _ = rwkv6.rwkv_cmix_train(lp["ffn"], h2, cfg)
        x = repl(_residual_add(x, repl(dx2), lp, "alpha_r2", cfg))
    return x, aux, centry


def _cstate_for(spec: LayerSpec, cperiod, idx):
    if cperiod is None:
        return None
    entry = cperiod[f"p{idx}"]
    if spec.ffn == "rwkv_cmix" and spec.mixer == "rwkv6":
        return entry          # holds both tmix keys and "cmix"
    return entry


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    table = params["embed"]["table"]
    if cfg.frontend == "vision_stub":
        fe = params["frontend"]
        ximg = jax.nn.gelu(dense_apply(fe["w1"], batch["patch_embeds"]
                                       .astype(table.dtype), cfg.quant))
        ximg = dense_apply(fe["w2"], ximg, cfg.quant)
        xtxt = jnp.take(table, batch["tokens"], axis=0)
        x = jnp.concatenate([ximg, xtxt], axis=1)
    elif cfg.frontend == "audio_stub":
        x = dense_apply(params["frontend"]["w1"],
                        batch["frames"].astype(table.dtype), cfg.quant)
    else:
        x = jnp.take(table, batch["tokens"], axis=0)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def _vocab_bias(cfg: ModelConfig, dtype):
    """-inf on padded vocab slots."""
    iota = jnp.arange(cfg.padded_vocab)
    return jnp.where(iota < cfg.vocab_size, 0.0, -1e9).astype(dtype)


def forward(params: dict, batch: dict, cfg: ModelConfig, mode: str = "train",
            return_hidden: bool = False):
    """Returns (logits_or_hidden, aux, cache_periods_or_None)."""
    assert mode in ("train", "prefill")
    x, positions = _embed_inputs(params, batch, cfg)
    x = constrain(x, "batch", None, None)

    def period_body(carry, pp):
        x, aux = carry
        centries = {}
        for idx, spec in enumerate(cfg.period):
            x, aux_l, ce = _apply_position(pp[f"p{idx}"], spec, x, cfg,
                                           positions, mode, None, None)
            aux = aux + aux_l
            if mode == "prefill":
                centries[f"p{idx}"] = ce
        x = constrain(x, "batch", None, None)
        return (x, aux), centries

    body = period_body
    if cfg.remat == "full":
        body = jax.checkpoint(period_body, prevent_cse=False)
    (x, aux), cache_periods = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["periods"])

    x = norm_apply(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, aux, (cache_periods if mode == "prefill" else None)
    logits = dense_apply(params["lm_head"], x, cfg.quant)
    logits = logits + _vocab_bias(cfg, logits.dtype)
    logits = constrain(logits, "batch", None, "model")
    return logits, aux, (cache_periods if mode == "prefill" else None)


def _nll(logits: jax.Array, targets: jax.Array) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tl = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return lse - tl


def loss_fn(params: dict, batch: dict, cfg: ModelConfig):
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if cfg.ce_chunks > 1:
        # chunked CE: the (B, S, V) logits tensor never materializes —
        # each sequence chunk projects + reduces under jax.checkpoint, so
        # backward recomputes the chunk logits instead of saving them
        # (§Perf: the 256k-vocab archs are dominated by CE traffic).
        hidden, aux, _ = forward(params, batch, cfg, mode="train",
                                 return_hidden=True)
        B, S, _ = hidden.shape
        nc = cfg.ce_chunks
        while S % nc:
            nc -= 1
        bias = _vocab_bias(cfg, jnp.float32)

        @jax.checkpoint
        def chunk_nll(xc, tc):
            lc = dense_apply(params["lm_head"], xc, cfg.quant)
            return _nll(lc.astype(jnp.float32) + bias, tc)

        def body(_, inp):
            return None, chunk_nll(*inp)

        xcs = hidden.reshape(B, nc, S // nc, -1).swapaxes(0, 1)
        tcs = targets.reshape(B, nc, S // nc).swapaxes(0, 1)
        _, nll_c = jax.lax.scan(body, None, (xcs, tcs))
        nll = nll_c.swapaxes(0, 1).reshape(B, S)
    else:
        logits, aux, _ = forward(params, batch, cfg, mode="train")
        nll = _nll(logits, targets)
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
    else:
        ce = nll.mean()
    loss = ce + 1e-2 * aux
    metrics = {"loss": loss, "ce": ce, "aux": aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# caches / decode
# ---------------------------------------------------------------------------

def _cache_entry_shapes(cfg: ModelConfig, spec: LayerSpec, batch: int,
                        max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    e = {}
    if spec.mixer == "attn":
        dh, hkv = cfg.head_dim, cfg.n_kv_heads
        e["k"] = jnp.zeros((batch, max_len, hkv, dh), dtype)
        e["v"] = jnp.zeros((batch, max_len, hkv, dh), dtype)
    elif spec.mixer == "mamba":
        e.update(mamba.mamba_state_init(cfg, batch, dtype))
    elif spec.mixer == "rwkv6":
        e.update(rwkv6.rwkv_state_init(cfg, batch, dtype))
    if spec.ffn == "rwkv_cmix":
        e["cmix"] = {"shift": jnp.zeros((batch, cfg.d_model), dtype)}
    return e


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    one = {f"p{i}": _cache_entry_shapes(cfg, spec, batch, max_len)
           for i, spec in enumerate(cfg.period)}
    periods = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(), one)
    return {"pos": jnp.zeros((), jnp.int32), "periods": periods}


def cache_specs(cfg: ModelConfig, seq_shard: bool = False,
                kv_head_shard: bool = True) -> dict:
    """Logical-axis tuples per cache leaf (resolved by MeshRules).

    ``seq_shard``: shard KV time over the "seq" (data) axis — long_500k
    context parallelism.  ``kv_head_shard=False``: KV head count doesn't
    divide the model axis (e.g. qwen3 kv=4 over TP=16 would pad 4x HBM);
    shard KV time over "model" instead (flash-decoding split-KV).
    """
    if seq_shard:
        # long-context: batch==1, the "seq"(=data) axis takes the KV time
        # dim — batch must not also claim it (duplicate-axis spec)
        kv_b, kv_seq, kv_h = None, "seq", None
    elif kv_head_shard:
        kv_b, kv_seq, kv_h = "batch", None, "model"
    else:
        kv_b, kv_seq, kv_h = "batch", "model", None
    def entry(spec: LayerSpec) -> dict:
        e = {}
        if spec.mixer == "attn":
            e["k"] = (None, kv_b, kv_seq, kv_h, None)
            e["v"] = (None, kv_b, kv_seq, kv_h, None)
        elif spec.mixer == "mamba":
            e["h"] = (None, "batch", "model", None)
            e["conv"] = (None, "batch", None, "model")
        elif spec.mixer == "rwkv6":
            e["s"] = (None, "batch", "model", None, None)
            e["shift"] = (None, "batch", None)
        if spec.ffn == "rwkv_cmix":
            e["cmix"] = {"shift": (None, "batch", None)}
        return e

    periods = {f"p{i}": entry(spec) for i, spec in enumerate(cfg.period)}
    return {"pos": (), "periods": periods}


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cfg: ModelConfig):
    """tokens: (B, 1) int32. Returns (logits (B,1,V), new cache)."""
    assert not cfg.is_encoder, "encoder archs have no decode step"
    pos = cache["pos"]
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = constrain(x, "batch", None, None)

    def period_body(x, inp):
        pp, cper = inp
        new_entries = {}
        for idx, spec in enumerate(cfg.period):
            cst = _cstate_for(spec, cper, idx)
            x, _, ce = _apply_position(pp[f"p{idx}"], spec, x, cfg,
                                       None, "decode", cst, pos)
            new_entries[f"p{idx}"] = ce
        return x, new_entries

    x, new_periods = jax.lax.scan(period_body, x,
                                  (params["periods"], cache["periods"]))
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = dense_apply(params["lm_head"], x, cfg.quant)
    logits = logits + _vocab_bias(cfg, logits.dtype)
    return logits, {"pos": pos + 1, "periods": new_periods}


def prefill(params: dict, batch: dict, cfg: ModelConfig):
    """Full-context forward that also builds the decode cache."""
    logits, aux, cache_periods = forward(params, batch, cfg, mode="prefill")
    seq = logits.shape[1]
    return logits, {"pos": jnp.asarray(seq, jnp.int32),
                    "periods": cache_periods}


# ---------------------------------------------------------------------------
# paged serving cache (ServeEngine v2)
# ---------------------------------------------------------------------------
#
# Layout: attention positions hold *shared* page pools
# ``(num_pages, page, Hkv, Dh)`` (which request owns which page is the
# engine's page table, serving/paging.py); recurrent positions hold
# per-slot state ROWS ``(max_slots + 1, ...)`` — row ``max_slots`` is the
# scratch lane that padded lanes read/write so bucket padding never
# touches a live request: padded DECODE lanes gather/scatter it by slot
# id, and padded PREFILL lanes scatter their (frozen-at-zero) final
# state into it.  Prefill never READS the rows — prompt state always
# starts from zero, so a recycled slot's stale rows are dead by
# construction.  All entries carry the usual leading ``n_periods`` axis
# so the period scan is identical to train/decode.


def supports_paged_prefill(cfg: ModelConfig) -> bool:
    """Chunked paged prefill covers EVERY decoder period: attention
    positions scatter whole K/V pages, recurrent positions (mamba /
    rwkv6 / rwkv_cmix) thread chunk-resumable state — conv tail +
    SSM/WKV state + token shift — across chunk boundaries, order-exact
    (see mamba_prefill_chunk / rwkv_tmix_prefill_chunk).  Only frontend
    archs (vision/audio stubs) are excluded: their inputs aren't token
    prompts, so they take the exact-length per-request path."""
    return cfg.frontend == "none"


def init_paged_cache(cfg: ModelConfig, max_slots: int, num_pages: int,
                     page_size: int, kv_format: str = "fp") -> dict:
    """``kv_format`` (core/kv_quant.py) picks the attention pool storage:
    "fp" keeps cfg.dtype pages; "int8"/"sc" store int8 level pools plus a
    parallel per-position-per-head f32 scale pool (+ the sc int8 residual
    pool).  All-zero init dequantizes to exact 0 in every format, so the
    trash page and unwritten positions behave identically to fp."""
    check_kv_format(kv_format)
    dtype = jnp.dtype(cfg.dtype)
    rows = max_slots + 1                      # + scratch lane
    dh, hkv = cfg.head_dim, cfg.n_kv_heads

    def entry(spec: LayerSpec) -> dict:
        e = {}
        if spec.mixer == "attn":
            kv_dt = dtype if kv_format == "fp" else jnp.int8
            e["k_pages"] = jnp.zeros((num_pages, page_size, hkv, dh), kv_dt)
            e["v_pages"] = jnp.zeros((num_pages, page_size, hkv, dh), kv_dt)
            if kv_format != "fp":
                sshape = (num_pages, page_size, hkv)
                e["k_scale"] = jnp.zeros(sshape, jnp.float32)
                e["v_scale"] = jnp.zeros(sshape, jnp.float32)
            if kv_format == "sc":
                rshape = (num_pages, page_size, hkv, dh)
                e["k_resid"] = jnp.zeros(rshape, jnp.int8)
                e["v_resid"] = jnp.zeros(rshape, jnp.int8)
        elif spec.mixer == "mamba":
            e.update(mamba.mamba_state_init(cfg, rows, dtype))
        elif spec.mixer == "rwkv6":
            e.update(rwkv6.rwkv_state_init(cfg, rows, dtype))
        if spec.ffn == "rwkv_cmix":
            e["cmix"] = {"shift": jnp.zeros((rows, cfg.d_model), dtype)}
        return e

    one = {f"p{i}": entry(spec) for i, spec in enumerate(cfg.period)}
    periods = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(), one)
    return {"periods": periods}


def paged_cache_specs(cfg: ModelConfig, kv_format: str = "fp") -> dict:
    """Logical-axis tuples per paged-cache leaf (shard_tree(logical=True)).

    KV page pools shard over their head axis ("model" carries KV heads —
    each device holds every page but only its heads); recurrent state
    rows shard their channel axis the same way.  Page/row axes stay
    unsharded: which page a request owns is HOST bookkeeping
    (serving/paging.py) and must remain device-count-agnostic.  Leaves
    whose channel count doesn't divide the mesh axis degrade to
    replicated via ``fit_spec``.  ``kv_format`` must match
    :func:`init_paged_cache`'s — ``shard_tree`` maps the spec tree over
    the cache tree leaf-for-leaf, so the scale/residual specs exist
    exactly when their pools do (same head axis over "model").
    """
    check_kv_format(kv_format)
    def entry(spec: LayerSpec) -> dict:
        e = {}
        if spec.mixer == "attn":
            # (n_periods, num_pages, page, Hkv, Dh)
            e["k_pages"] = (None, None, None, "model", None)
            e["v_pages"] = (None, None, None, "model", None)
            if kv_format != "fp":
                # (n_periods, num_pages, page, Hkv)
                e["k_scale"] = (None, None, None, "model")
                e["v_scale"] = (None, None, None, "model")
            if kv_format == "sc":
                e["k_resid"] = (None, None, None, "model", None)
                e["v_resid"] = (None, None, None, "model", None)
        elif spec.mixer == "mamba":
            # h: (n_periods, rows, d_inner, n); conv: (…, k-1, d_inner)
            e["h"] = (None, None, "model", None)
            e["conv"] = (None, None, None, "model")
        elif spec.mixer == "rwkv6":
            # s: (n_periods, rows, heads, dh, dh)
            e["s"] = (None, None, "model", None, None)
            e["shift"] = (None, None, None)
        if spec.ffn == "rwkv_cmix":
            e["cmix"] = {"shift": (None, None, None)}
        return e

    periods = {f"p{i}": entry(spec) for i, spec in enumerate(cfg.period)}
    return {"periods": periods}


# shared page-pool leaves (passed whole to every lane, never gathered by
# slot id) vs per-slot state rows; the scale/residual pools of the
# compressed kv_formats are pools like the pages they describe
_POOL_KEYS = ("k_pages", "v_pages", "k_scale", "v_scale",
              "k_resid", "v_resid")


def paged_decode_step(params: dict, cache: dict, tokens: jax.Array,
                      slot_ids: jax.Array, page_tables: jax.Array,
                      lengths: jax.Array, cfg: ModelConfig):
    """One batched decode step over the paged cache — every active slot
    advances one token in a single traced computation.

    tokens / slot_ids / lengths: (S,) int32 (S = padded slot bucket);
    page_tables: (S, maxp) int32.  Padded lanes carry slot_id ==
    max_slots (scratch row), length 0 and trash-page tables.  Returns
    (logits (S, V), new cache); retraces only when S or maxp change.

    Attention inside runs the flash-decoding paged Pallas kernel via
    kernels/dispatch (``attn_backend_scope`` pins it; the XLA gather is
    the reference oracle, and the only path under active mesh rules).
    """
    assert not cfg.is_encoder, "encoder archs have no decode step"
    x = jnp.take(params["embed"]["table"], tokens[:, None], axis=0)  # (S,1,D)
    x = constrain(x, None, None, None)   # embed table is vocab-sharded

    def period_body(x, inp):
        pp, cper = inp
        new_entries = {}
        for idx, spec in enumerate(cfg.period):
            entry = cper[f"p{idx}"]
            cst = {k: (v if k in _POOL_KEYS
                       else jax.tree.map(lambda a: a[slot_ids], v))
                   for k, v in entry.items()}
            cst["page_tables"] = page_tables
            x, _, ce = _apply_position(pp[f"p{idx}"], spec, x, cfg,
                                       None, "decode", cst, lengths)
            new_entries[f"p{idx}"] = {
                k: (v if k in _POOL_KEYS
                    else jax.tree.map(
                        lambda full, rows: full.at[slot_ids].set(rows),
                        entry[k], v))
                for k, v in ce.items()}
        return x, new_entries

    x, new_periods = jax.lax.scan(period_body, x,
                                  (params["periods"], cache["periods"]))
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = dense_apply(params["lm_head"], x, cfg.quant)
    logits = logits + _vocab_bias(cfg, logits.dtype)
    # serving lm_head is column-parallel: pin the product's vocab axis to
    # "model" so GSPMD gathers exactly once — the sampler (or argmax)
    # downstream re-pins its crop to replicated, which is what makes the
    # categorical draw identical on and off the mesh
    logits = constrain(logits, None, None, "model")
    return logits[:, 0], {"periods": new_periods}


def paged_verify_step(params: dict, cache: dict, tokens: jax.Array,
                      slot_ids: jax.Array, page_tables: jax.Array,
                      lengths: jax.Array, cfg: ModelConfig):
    """Batched multi-token speculative-VERIFY step over the paged cache.

    tokens: (S, T) int32 — per lane, the last committed token followed
    by the T-1 draft tokens, occupying cache positions ``lengths`` ..
    ``lengths + T - 1``; other args exactly as
    :func:`paged_decode_step`.  One target-datapath forward scores the
    whole window: attention runs all T queries in parallel under
    per-query causal horizons (:func:`attention.attn_verify_paged`);
    recurrent mixers scan their decode-mode update per token
    (:func:`_verify_scan`), so logits row t is bit-arithmetically the
    decode-step logits after committing window tokens ``0..t`` — the
    spec-on == spec-off identity the differential tests pin.

    Returns ``(logits (S, T, V), new_cache, snaps)``:

    * the new cache holds the target-datapath K/V scatter for all T
      window positions (rows past the accepted prefix are dead — they
      sit beyond the committed length, so every later read masks them
      out and every later write lands on them first), while recurrent
      state ROWS are deliberately left untouched;
    * ``snaps`` stacks each period's post-token recurrent state along
      ``(n_periods, T, S, ...)`` — the engine picks lane s's
      accepted-prefix snapshot with :func:`select_state_snapshot` and
      commits it via :func:`scatter_state_rows`, all inside the same
      jit.
    """
    assert not cfg.is_encoder, "encoder archs have no decode step"
    x = jnp.take(params["embed"]["table"], tokens, axis=0)     # (S,T,D)
    x = constrain(x, None, None, None)

    def period_body(x, inp):
        pp, cper = inp
        new_entries, snaps = {}, {}
        for idx, spec in enumerate(cfg.period):
            entry = cper[f"p{idx}"]
            cst = {k: (v if k in _POOL_KEYS
                       else jax.tree.map(lambda a: a[slot_ids], v))
                   for k, v in entry.items()}
            cst["page_tables"] = page_tables
            x, _, ce = _apply_position(pp[f"p{idx}"], spec, x, cfg,
                                       None, "verify", cst, lengths)
            new_entries[f"p{idx}"] = {
                k: (ce[k] if k in _POOL_KEYS else entry[k])
                for k in entry}
            snaps[f"p{idx}"] = {k: v for k, v in ce.items()
                                if k not in _POOL_KEYS}
        return x, (new_entries, snaps)

    x, (new_periods, snaps) = jax.lax.scan(
        period_body, x, (params["periods"], cache["periods"]))
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = dense_apply(params["lm_head"], x, cfg.quant)
    logits = logits + _vocab_bias(cfg, logits.dtype)
    logits = constrain(logits, None, None, "model")
    return logits, {"periods": new_periods}, snaps


def gather_state_rows(cache: dict, slot_ids: jax.Array) -> dict:
    """Snapshot the per-slot recurrent state rows (leaves
    ``(n_periods, S, ...)``) — the pre-draft checkpoint the engine
    restores after a draft pass, so the drafter's approximate
    arithmetic never contaminates the target-datapath state."""
    return jax.tree.map(
        lambda a: a[:, slot_ids],
        {p: {k: v for k, v in e.items() if k not in _POOL_KEYS}
         for p, e in cache["periods"].items()})


def scatter_state_rows(cache: dict, rows: dict,
                       slot_ids: jax.Array) -> dict:
    """Write :func:`gather_state_rows`-shaped rows back into the cache
    (attention pools pass through untouched)."""
    out = {}
    for p, e in cache["periods"].items():
        out[p] = {k: (v if k in _POOL_KEYS
                      else jax.tree.map(
                          lambda full, rw: full.at[:, slot_ids].set(rw),
                          v, rows[p][k]))
                  for k, v in e.items()}
    return {"periods": out}


def select_state_snapshot(snaps: dict, m: jax.Array) -> dict:
    """Pick one per-token state snapshot per lane.

    snaps: :func:`paged_verify_step` output, leaves
    ``(n_periods, T, S, ...)``; m: (S,) int32 in ``[0, T-1]`` — the
    window index of the last committed token.  Returns rows shaped for
    :func:`scatter_state_rows` (leaves ``(n_periods, S, ...)``): lane
    s's state after consuming window tokens ``0..m[s]``."""
    def sel(leaf):
        S = leaf.shape[2]
        return leaf[:, m, jnp.arange(S)]
    return jax.tree.map(sel, snaps)


def _group_state_entry(cfg: ModelConfig, spec: LayerSpec, G: int,
                       dtype) -> dict:
    """Zero recurrent state for the G prefill lanes (decode row shapes,
    batch axis = lane)."""
    e = {}
    if spec.mixer == "mamba":
        e.update(mamba.mamba_state_init(cfg, G, dtype))
    elif spec.mixer == "rwkv6":
        e.update(rwkv6.rwkv_state_init(cfg, G, dtype))
    if spec.ffn == "rwkv_cmix":
        e["cmix"] = {"shift": jnp.zeros((G, cfg.d_model), dtype)}
    return e


def _group_state_specs(cfg: ModelConfig, idx: int) -> dict:
    """Logical pins for the carried group state, DERIVED from
    :func:`paged_cache_specs` by dropping the leading period axis (the
    rows axis becomes the lane axis, replicated either way) — same
    channel axes over "model", one source of truth, so the
    chunk-to-chunk carry keeps the cache's sharding and mesh-on prefill
    stays token-identical to mesh-off."""
    entry = paged_cache_specs(cfg)["periods"][f"p{idx}"]
    return jax.tree.map(lambda lg: tuple(lg)[1:],
                        {k: v for k, v in entry.items()
                         if k not in _POOL_KEYS},
                        is_leaf=lambda s: isinstance(s, tuple))


def paged_prefill(params: dict, cache: dict, tokens: jax.Array,
                  page_tables: jax.Array, prompt_lens: jax.Array,
                  cfg: ModelConfig, *, chunk: int,
                  slot_ids: jax.Array | None = None):
    """Batched *chunked* prefill writing straight into the decode cache
    layout, for EVERY decoder period type (:func:`supports_paged_prefill`).

    tokens: (G, L) right-padded prompts (L a multiple of ``chunk``,
    ``chunk`` a multiple of the page size); page_tables: (G, maxp)
    covering at least ceil(L/page) entries (padding = trash page);
    prompt_lens: (G,); slot_ids: (G,) int32 slot of each lane (padding =
    the scratch row) — required when the period holds recurrent state.
    Each chunk runs the full period scan then dies — peak logits cost is
    (G, chunk, V) never (G, L, V).  Attention positions scatter the
    chunk's K/V as whole pages and attend over the pages written so far
    (the chunked paged-prefill Pallas kernel via kernels/dispatch, same
    backend chain as decode);
    recurrent positions consume the carried state (conv tail + SSM/WKV
    state + token shifts, zeros before the first chunk) and emit the
    updated carry, with right-padded positions masked so each lane's
    state freezes at its last real token (``valid`` select — exact, so
    any chunk size reproduces the one-shot prefill bit for bit).  The
    final carries scatter into the per-slot state rows at the end, all
    inside the caller's jit.  Returns (last_token_logits (G, V), new
    cache).
    """
    assert supports_paged_prefill(cfg), \
        "paged prefill serves token prompts only (frontend == none)"
    G, L = tokens.shape
    assert L % chunk == 0, (L, chunk)
    table = params["embed"]["table"]
    h_last = jnp.zeros((G, cfg.d_model), table.dtype)
    # split the cache: shared page pools ride the chunk loop; per-slot
    # state rows are untouched until the final scatter (prompt state
    # starts from zero, never from a previous occupant's rows)
    pools, rows = {}, {}
    for i in range(len(cfg.period)):
        pe = cache["periods"][f"p{i}"]
        pools[f"p{i}"] = {k: v for k, v in pe.items() if k in _POOL_KEYS}
        rows[f"p{i}"] = {k: v for k, v in pe.items()
                         if k not in _POOL_KEYS}
    has_state = len(jax.tree_util.tree_leaves(rows)) > 0
    if has_state and slot_ids is None:
        raise ValueError("recurrent periods need slot_ids to place their "
                         "carried state rows")
    one = {f"p{i}": _group_state_entry(cfg, spec, G, table.dtype)
           for i, spec in enumerate(cfg.period)}
    gstate = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), one)

    for c in range(L // chunk):
        start = c * chunk
        xc = jnp.take(table, tokens[:, start:start + chunk], axis=0)
        xc = constrain(xc, None, None, None)
        valid = (start + jnp.arange(chunk, dtype=jnp.int32))[None, :] \
            < prompt_lens[:, None]                        # (G, chunk)

        def period_body(x, inp, start=start, valid=valid):
            pp, cper, gsper = inp
            new_pools, new_gs = {}, {}
            for idx, spec in enumerate(cfg.period):
                cst = dict(cper[f"p{idx}"])
                cst.update(gsper[f"p{idx}"])
                cst["page_tables"] = page_tables
                cst["start"] = start
                cst["valid"] = valid
                x, _, ce = _apply_position(pp[f"p{idx}"], spec, x, cfg,
                                           None, "paged_prefill", cst, None)
                new_pools[f"p{idx}"] = {k: v for k, v in ce.items()
                                        if k in _POOL_KEYS}
                new_gs[f"p{idx}"] = constrain_tree(
                    {k: v for k, v in ce.items() if k not in _POOL_KEYS},
                    _group_state_specs(cfg, idx))
            return x, (new_pools, new_gs)

        xc, (pools, gstate) = jax.lax.scan(
            period_body, xc, (params["periods"], pools, gstate))
        # keep the hidden state of each request's last real token
        last = prompt_lens - 1 - start
        rws = jnp.take_along_axis(
            xc, jnp.clip(last, 0, chunk - 1)[:, None, None], axis=1)[:, 0]
        h_last = jnp.where(((last >= 0) & (last < chunk))[:, None],
                           rws, h_last)

    # scatter each lane's final carry into its slot's state rows (padded
    # lanes land in the scratch row, whose contents no live request
    # reads)
    new_periods = {}
    for i in range(len(cfg.period)):
        entry = dict(pools[f"p{i}"])
        for name, rv in rows[f"p{i}"].items():
            gv = gstate[f"p{i}"][name]
            entry[name] = jax.tree.map(
                lambda full, g: full.at[:, slot_ids].set(
                    g.astype(full.dtype)), rv, gv)
        new_periods[f"p{i}"] = entry

    h = norm_apply(params["final_norm"], h_last[:, None, :], cfg.norm)
    logits = dense_apply(params["lm_head"], h, cfg.quant)[:, 0]
    logits = logits + _vocab_bias(cfg, logits.dtype)
    # same vocab-axis pin as paged_decode_step: sampling the first
    # generated token must see mesh-invariant logit rows
    logits = constrain(logits, None, "model")
    return logits, {"periods": new_periods}


# ---------------------------------------------------------------------------
# batch construction (shared by data pipeline / dryrun input_specs)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, kind: str) -> dict:
    """Logical sharding tuples for each batch field."""
    if cfg.frontend == "vision_stub":
        d = {"patch_embeds": ("batch", None, None), "tokens": ("batch", None)}
    elif cfg.frontend == "audio_stub":
        d = {"frames": ("batch", None, None)}
    else:
        d = {"tokens": ("batch", None)}
    if kind == "train":
        d["targets"] = ("batch", None)
        d["loss_mask"] = ("batch", None)
    return d


def make_dummy_batch(cfg: ModelConfig, batch: int, seq: int, kind: str,
                     img_tokens: int = 0) -> dict:
    """Concrete (tiny) batches for smoke tests; dryrun uses ShapeDtypeStructs
    with the same structure (launch/dryrun.py)."""
    out = {}
    if cfg.frontend == "vision_stub":
        img = img_tokens or max(seq // 4, 1)
        out["patch_embeds"] = jnp.zeros((batch, img, 1024), jnp.bfloat16)
        out["tokens"] = jnp.zeros((batch, seq - img), jnp.int32)
    elif cfg.frontend == "audio_stub":
        out["frames"] = jnp.zeros((batch, seq, 512), jnp.bfloat16)
    else:
        out["tokens"] = jnp.zeros((batch, seq), jnp.int32)
    if kind == "train":
        out["targets"] = jnp.zeros((batch, seq), jnp.int32)
        out["loss_mask"] = jnp.ones((batch, seq), jnp.float32)
    return out

"""Model zoo: a single unified implementation covering all assigned archs.

transformer.py — period-scan LM (dense/MoE/SSM/hybrid/encoder/VLM)
attention.py   — GQA flash attention (train/prefill) + cached decode
ffn.py         — gated / squared-ReLU FFN
moe.py         — GShard-style expert-parallel MoE
mamba.py       — chunked selective scan (Jamba)
rwkv6.py       — RWKV-6 time-mix / channel-mix
"""

from . import attention, common, ffn, mamba, moe, rwkv6, transformer
from .transformer import (batch_specs, cache_specs, decode_step, forward,
                          gather_state_rows, init_cache, init_paged_cache,
                          init_params, loss_fn, make_dummy_batch,
                          paged_cache_specs, paged_decode_step, paged_prefill,
                          paged_verify_step, param_specs, prefill,
                          scatter_state_rows, select_state_snapshot,
                          supports_paged_prefill)

__all__ = [
    "attention", "common", "ffn", "mamba", "moe", "rwkv6", "transformer",
    "batch_specs", "cache_specs", "decode_step", "forward",
    "gather_state_rows", "init_cache", "init_paged_cache", "init_params",
    "loss_fn", "make_dummy_batch", "paged_cache_specs", "paged_decode_step",
    "paged_prefill", "paged_verify_step", "param_specs", "prefill",
    "scatter_state_rows", "select_state_snapshot", "supports_paged_prefill",
]

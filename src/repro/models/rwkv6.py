"""RWKV-6 "Finch" mixer: data-dependent-decay linear attention.

Attention-free: the time-mix recurrence
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
carries a per-head (Dh x Dh) state, so long_500k decode is O(1) in context
length.  Training/prefill runs the recurrence as a ``lax.scan`` over
tokens (the baseline; the chunked-GLA matmul form is a §Perf hillclimb
candidate — see EXPERIMENTS.md).

The decay w_t = exp(-exp(w0 + lora(x))) is a multiplicative data-dependent
recurrence — not SC-SI-realizable (DESIGN.md §4) — kept f32; the R/K/V/G/O
projections and the channel-mix matmuls (whose squared-ReLU is *exactly*
SI-realizable) are SC-quantized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from .common import (DATA, MODEL, dense_apply, dense_init, dense_spec,
                     norm_apply, norm_init, norm_spec)

__all__ = ["rwkv_tmix_init", "rwkv_tmix_spec", "rwkv_tmix_train",
           "rwkv_tmix_decode", "rwkv_tmix_prefill_chunk",
           "rwkv_cmix_init", "rwkv_cmix_spec", "rwkv_cmix_train",
           "rwkv_cmix_decode", "rwkv_cmix_prefill_chunk",
           "rwkv_state_init"]

_MIX_NAMES = ("w", "k", "v", "r", "g")


def _n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_tmix_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, dh = _n_heads(cfg), cfg.rwkv_head_dim
    lora = max(32, d // 64)
    lora_w = cfg.rwkv_lora_w or max(64, d // 32)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    q = cfg.quant
    p = {
        "maa_x": jnp.zeros((d,), jnp.float32),
        "maa": jnp.zeros((5, d), jnp.float32),            # w,k,v,r,g
        "tm_w1": (jax.random.normal(ks[0], (d, 5 * lora), jnp.float32)
                  * 1e-2).astype(dtype),
        "tm_w2": (jax.random.normal(ks[1], (5, lora, d), jnp.float32)
                  * 1e-2).astype(dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "dw1": (jax.random.normal(ks[2], (d, lora_w), jnp.float32)
                * 1e-2).astype(dtype),
        "dw2": (jax.random.normal(ks[3], (lora_w, d), jnp.float32)
                * 1e-2).astype(dtype),
        "u": jnp.zeros((h, dh), jnp.float32),
        "wr": dense_init(ks[4], d, d, q, dtype=dtype),
        "wk": dense_init(ks[5], d, d, q, dtype=dtype),
        "wv": dense_init(ks[6], d, d, q, dtype=dtype),
        "wg": dense_init(ks[7], d, d, q, dtype=dtype),
        "wo": dense_init(jax.random.fold_in(key, 99), d, d, q, dtype=dtype),
        "ln_x": norm_init(d, "layernorm"),                # per-head groupnorm
    }
    return p


def rwkv_tmix_spec(cfg: ModelConfig) -> dict:
    # LoRA adapters (tm_w1/dw1 etc, <=0.5% of params) are REPLICATED:
    # sharding their contraction dim turns every adapter matmul into a
    # (B,S,*) activation all-reduce — 260 GB/step on train_4k (§Perf).
    q = cfg.quant
    return {
        # tm_w2 stays output-sharded: replicating it makes every ddlerp
        # output full-width on every chip (+14 TB/step memory for -14 GB
        # wire — measured, §Perf cell B iter 3, reverted)
        "maa_x": P(None), "maa": P(None, None),
        "tm_w1": P(None, None), "tm_w2": P(None, None, MODEL),
        "w0": P(MODEL), "dw1": P(None, None), "dw2": P(None, MODEL),
        "u": P(MODEL, None),
        "wr": dense_spec(DATA, MODEL, q), "wk": dense_spec(DATA, MODEL, q),
        "wv": dense_spec(DATA, MODEL, q), "wg": dense_spec(DATA, MODEL, q),
        "wo": dense_spec(MODEL, DATA, q),
        "ln_x": norm_spec("layernorm"),
    }


def _ddlerp(p, x, sx):
    """Data-dependent token-shift interpolation (the Finch trick)."""
    xxx = x + sx * p["maa_x"]
    lora = jnp.tanh(xxx @ p["tm_w1"].astype(x.dtype))
    B, S, _ = lora.shape
    lora = lora.reshape(B, S, 5, -1)
    adj = jnp.einsum("bsfl,fld->bsfd", lora, p["tm_w2"].astype(x.dtype))
    mixed = []
    for i, _ in enumerate(_MIX_NAMES):
        mi = p["maa"][i] + adj[:, :, i, :].astype(jnp.float32)
        mixed.append(x + sx * mi.astype(x.dtype))
    return mixed                                           # xw, xk, xv, xr, xg


def _decay(p, xw):
    ww = jnp.tanh(xw @ p["dw1"].astype(xw.dtype)) @ p["dw2"].astype(xw.dtype)
    return jnp.exp(-jnp.exp(p["w0"] + ww.astype(jnp.float32)))  # (B,S,D) in (0,1)


def _wkv_scan(r, k, v, w, u, s0, valid=None):
    """r,k,v: (B,S,H,Dh) bf16; w f32 decay; s0: (B,H,Dh,Dh) f32 state.

    The recurrence is head-local: carry and time-major inputs are pinned
    head-sharded ("model") so every step is collective-free.  r/k/v ride
    in the compute dtype (the f32 state/decay carry the numerics); the
    emitted y is compute-dtype too — halves the scan's residual traffic.

    ``valid``: optional (B, S) bool — masked steps leave the carried
    state untouched (``where`` is an exact select), so right-padded
    prefill lanes freeze at their last real token while the per-token
    op sequence on valid tokens stays bit-identical to the unmasked
    scan (chunk-split invariance for serving prefill).
    """
    def step(s, inp):
        rt, kt, vt, wt, mt = inp                           # (B,H,Dh) f32
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = jnp.where(mt[:, None, None, None],
                      wt[..., :, None] * s + kv, s)
        return s, y

    # note: no sharding constraints here — the recurrence inherits the
    # head sharding of r/k/v/w and stays collective-free (verified by HLO
    # attribution; forcing constraints only added layout copies — §Perf)
    tm = lambda t: jnp.moveaxis(t, 1, 0).astype(jnp.float32)  # time-major
    if valid is None:
        valid = jnp.ones(r.shape[:2], bool)
    sT, ys = jax.lax.scan(step, s0, (tm(r), tm(k), tm(v), tm(w),
                                     jnp.moveaxis(valid, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), sT                      # (B,S,H,Dh), state


def _wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """GLA-style quasi-matmul wkv (exactly the recurrence, chunked).

    Per chunk of C tokens (log-space decays, all exponent differences
    <= 0 so no overflow at any decay strength):

        y_t = (r_t . e^{L_{t-1}}) @ S_0                        (inter)
            + sum_{s<t} [sum_k r_t k_s e^{L_{t-1}-L_s}]_k v_s  (intra)
            + ((r_t . u) @ k_t) v_t                            (bonus)
        S_C = e^{L_C} . S_0 + sum_s (k_s . e^{L_C - L_s}) v_s^T

    Replaces the S-step serial scan with S/C steps of batched dense work
    — the MXU-friendly form the token recurrence can't reach (§Perf).
    """
    B, S, H, D = r.shape
    C = min(chunk, S)
    while S % C:
        C -= 1
    nc = S // C
    f32 = jnp.float32

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, C, H, D), 1, 0).astype(f32)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    logw = jnp.log(jnp.clip(to_chunks(w), 1e-30, 1.0))
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)          # strict lower

    def chunk_step(s0, inp):
        rc, kc, vc, lw = inp                              # (B,C,H,D)
        L = jnp.cumsum(lw, axis=1)                        # L_t
        Lprev = L - lw                                    # L_{t-1}
        r_w = rc * jnp.exp(Lprev)
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_w, s0)
        # intra attention matrix with per-channel decays
        diff = Lprev[:, :, None, :, :] - L[:, None, :, :, :]  # (B,t,s,H,K)
        diff = jnp.where(tri[None, :, :, None, None], diff, -1e30)
        a = jnp.einsum("bthk,bshk,btshk->bths", rc, kc, jnp.exp(diff))
        bonus = jnp.einsum("bthk,bthk->bth", rc * u[None, None], kc)
        a = a + bonus[..., None] * jnp.eye(C)[None, :, None, :]
        y = y_inter + jnp.einsum("bths,bshv->bthv", a, vc)
        # carry state across the chunk boundary
        L_C = L[:, -1]                                    # (B,H,K)
        k_w = kc * jnp.exp(L_C[:, None] - L)
        s1 = s0 * jnp.exp(L_C)[..., None] \
            + jnp.einsum("bshk,bshv->bhkv", k_w, vc)
        return s1, y

    sT, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, logw))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, D)
    return y, sT


def _tmix_core(p, x, sx, cfg, s0, valid=None, force_scan=False):
    B, S, d = x.shape
    h, dh = _n_heads(cfg), cfg.rwkv_head_dim
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    w = _decay(p, xw).reshape(B, S, h, dh)
    r = dense_apply(p["wr"], xr, cfg.quant).reshape(B, S, h, dh)
    k = dense_apply(p["wk"], xk, cfg.quant).reshape(B, S, h, dh)
    v = dense_apply(p["wv"], xv, cfg.quant).reshape(B, S, h, dh)
    g = jax.nn.silu(dense_apply(p["wg"], xg, cfg.quant))
    # force_scan: serving prefill must be chunk-split-invariant, which
    # only the token recurrence is (the GLA form's intra-chunk matmul
    # tree depends on where the chunk boundaries fall)
    if cfg.rwkv_wkv_impl == "chunked" and S > 1 and not force_scan:
        y, sT = _wkv_chunked(r, k, v, w, p["u"], s0, cfg.rwkv_chunk)
    else:
        y, sT = _wkv_scan(r, k, v, w, p["u"], s0, valid=valid)
    y = y.reshape(B, S, d)
    y = norm_apply(p["ln_x"], y, "layernorm", eps=1e-5, groups=h)
    out = dense_apply(p["wo"], (y * g).astype(x.dtype), cfg.quant)
    return out, sT


def rwkv_tmix_train(p: dict, x: jax.Array, cfg: ModelConfig):
    """Returns (y, (state_T, x_last)) for prefill caching."""
    B, S, d = x.shape
    h, dh = _n_heads(cfg), cfg.rwkv_head_dim
    prev = jnp.pad(x[:, :-1, :], ((0, 0), (1, 0), (0, 0)))
    sx = prev - x
    s0 = jnp.zeros((B, h, dh, dh), jnp.float32)
    out, sT = _tmix_core(p, x, sx, cfg, s0)
    return out, (sT, x[:, -1, :])


def rwkv_tmix_decode(p: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    """x: (B,1,D); state {"s": (B,H,Dh,Dh), "shift": (B,D)}."""
    sx = state["shift"][:, None, :].astype(x.dtype) - x
    out, sT = _tmix_core(p, x, sx, cfg, state["s"])
    return out, {"s": sT, "shift": x[:, 0, :]}


def _last_valid(x, valid, fallback):
    """Each lane's last valid token row (the carried token-shift state);
    lanes with no valid token this chunk keep ``fallback``."""
    if valid is None:
        return x[:, -1, :]
    nv = jnp.sum(valid, axis=1).astype(jnp.int32)          # (B,)
    idx = jnp.clip(nv - 1, 0, x.shape[1] - 1)
    rows = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return jnp.where((nv > 0)[:, None], rows, fallback.astype(x.dtype))


def rwkv_tmix_prefill_chunk(p: dict, x: jax.Array, cfg: ModelConfig,
                            state: dict, valid: jax.Array | None = None):
    """Chunk-resumable tmix prefill, consuming and emitting the decode
    state shapes (``{"s": (B,H,Dh,Dh) f32, "shift": (B,D)}`` — zeros at
    sequence start).  The wkv recurrence runs as the PER-TOKEN scan
    regardless of ``cfg.rwkv_wkv_impl`` so splitting a prompt at any
    chunk boundary replays the identical op sequence (bit-exact — see
    :func:`_wkv_scan`); ``valid`` masks right-padded positions, freezing
    both the wkv state and the token-shift carry at the last real token.
    """
    prev = jnp.concatenate([state["shift"][:, None, :].astype(x.dtype),
                            x[:, :-1, :]], axis=1)
    out, sT = _tmix_core(p, x, prev - x, cfg, state["s"], valid=valid,
                         force_scan=True)
    return out, {"s": sT, "shift": _last_valid(x, valid, state["shift"])}


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------

def rwkv_cmix_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    q = cfg.quant
    return {
        "mk": jnp.zeros((d,), jnp.float32),
        "mr": jnp.zeros((d,), jnp.float32),
        "wk": dense_init(ks[0], d, f, q, dtype=dtype),
        "wv": dense_init(ks[1], f, d, q, dtype=dtype),
        "wr": dense_init(ks[2], d, d, q, dtype=dtype),
    }


def rwkv_cmix_spec(cfg: ModelConfig) -> dict:
    q = cfg.quant
    return {"mk": P(None), "mr": P(None),
            "wk": dense_spec(DATA, MODEL, q),
            "wv": dense_spec(MODEL, DATA, q),
            "wr": dense_spec(DATA, None, q)}


def _cmix_core(p, x, sx, cfg):
    xk = x + sx * p["mk"].astype(x.dtype)
    xr = x + sx * p["mr"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense_apply(p["wk"], xk, cfg.quant)))
    kv = dense_apply(p["wv"], k, cfg.quant)
    return jax.nn.sigmoid(dense_apply(p["wr"], xr, cfg.quant)) * kv


def rwkv_cmix_train(p: dict, x: jax.Array, cfg: ModelConfig):
    prev = jnp.pad(x[:, :-1, :], ((0, 0), (1, 0), (0, 0)))
    return _cmix_core(p, x, prev - x, cfg), x[:, -1, :]


def rwkv_cmix_decode(p: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    sx = state["shift"][:, None, :].astype(x.dtype) - x
    return _cmix_core(p, x, sx, cfg), {"shift": x[:, 0, :]}


def rwkv_cmix_prefill_chunk(p: dict, x: jax.Array, cfg: ModelConfig,
                            state: dict, valid: jax.Array | None = None):
    """Chunk-resumable cmix prefill: the only cross-token coupling is
    the one-token shift, so carrying ``{"shift": (B, D)}`` makes any
    chunk split bit-exact (everything else is per-token elementwise)."""
    prev = jnp.concatenate([state["shift"][:, None, :].astype(x.dtype),
                            x[:, :-1, :]], axis=1)
    return (_cmix_core(p, x, prev - x, cfg),
            {"shift": _last_valid(x, valid, state["shift"])})


def rwkv_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, dh, d = _n_heads(cfg), cfg.rwkv_head_dim, cfg.d_model
    return {"s": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "shift": jnp.zeros((batch, d), dtype)}

"""Shared building blocks: SC-aware dense, norms, RoPE, embeddings.

Every projection in the zoo routes through :func:`dense_apply`, which is
where the paper's technique plugs into arbitrary architectures: with
``quant.mode == "sc_qat"`` the matmul becomes ternary-weight x thermometer-
activation fake-quant (LSQ), with ``"none"`` it is a plain matmul, and
with ``"sc_int"`` it runs the silicon-equivalent integer datapath
(``sc_linear_int_from_qat``: int8 codes x ternary weights, int32 / BSN
accumulate) — what ServeEngine's ``datapath="sc_int"`` serves.

Param/spec convention: each ``*_init`` returns a pytree of arrays and each
``*_spec`` returns the matching pytree of ``PartitionSpec`` (physical axes
``"data"`` = FSDP, ``"model"`` = TP).  Stacked-layer leading axes are added
by the caller (transformer.py) with ``add_leading_none``.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.sc_layers import SCQuantConfig, sc_linear_qat

DATA, MODEL = "data", "model"

__all__ = [
    "DATA", "MODEL",
    "dense_init", "dense_spec", "dense_apply",
    "norm_init", "norm_spec", "norm_apply",
    "embed_init", "embed_spec",
    "rope_freqs", "apply_rope",
    "ACT_FNS", "add_leading_none", "softcap", "big_neg",
]


def big_neg(dtype) -> float:
    return float(jnp.finfo(dtype).min) * 0.5


# ---------------------------------------------------------------------------
# dense (SC-quantization aware)
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int, quant: SCQuantConfig,
               dtype=jnp.bfloat16, scale: float | None = None) -> dict:
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)
    p = {"w": w}
    if quant.enabled:
        if quant.per_channel:
            aw = jnp.full((d_out,), 1.4 * std * 0.8, jnp.float32)
        else:
            aw = jnp.asarray(1.4 * std * 0.8, jnp.float32)
        p["alpha_w"] = aw
        p["alpha_a"] = jnp.asarray(2.0 / math.sqrt(max(quant.act_half, 1)),
                                   jnp.float32)
    return p


def dense_spec(in_axis: str | None, out_axis: str | None,
               quant: SCQuantConfig) -> dict:
    """PartitionSpecs for one dense layer's params.

    Axis convention: training uses Megatron pairs (column-parallel
    ``(DATA, MODEL)`` feeding row-parallel ``(MODEL, DATA)``); the
    serving layout (attn_spec/ffn_spec ``serving=True``) passes
    ``(None, MODEL)`` everywhere — output channels shard, contractions
    stay device-local so the per-channel SC accumulators never split
    across chips (see serving/README.md).  Per-channel ``alpha_w``
    follows the out axis so the quantizer scale lives with its column.
    """
    s = {"w": P(in_axis, out_axis)}
    if quant.enabled:
        s["alpha_w"] = P(out_axis) if quant.per_channel else P()
        s["alpha_a"] = P()
    return s


def dense_apply(p: dict, x: jax.Array, quant: SCQuantConfig) -> jax.Array:
    """The SC integration point (see module docstring).

    Quantizer math runs f32 (LSQ grads need it) but the fake-quant VALUES
    are cast back to the compute dtype before the matmul: quantized values
    are exact small multiples of alpha, so bf16 carries them with ~1e-3
    relative rounding while halving weight-gather traffic and doubling MXU
    rate vs an f32 datapath (§Perf iteration 1).
    """
    from repro.core.quant import ternary_weight_quant, thermometer_act_quant
    if not quant.enabled:
        return x @ p["w"]
    if quant.mode == "sc_int":
        # serving: the silicon-equivalent integer path (int8 x ternary ->
        # int32 accumulate, optionally through the approximate BSN adder)
        from repro.core.sc_layers import sc_linear_int_from_qat
        return sc_linear_int_from_qat(p, x, quant)
    if quant.mode != "sc_qat":
        return x @ p["w"]
    x_fq = thermometer_act_quant(x, p["alpha_a"], quant.act_bsl)
    w_fq = ternary_weight_quant(p["w"], p["alpha_w"])
    return x_fq @ w_fq.astype(x_fq.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_spec(kind: str) -> dict:
    s = {"scale": P(None)}
    if kind == "layernorm":
        s["bias"] = P(None)
    return s


def norm_apply(p: dict, x: jax.Array, kind: str, eps: float = 1e-6,
               groups: int = 0) -> jax.Array:
    """rmsnorm / layernorm / (grouped layernorm when groups > 0, for RWKV)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if groups:
        shp = xf.shape[:-1] + (groups, xf.shape[-1] // groups)
        xg = xf.reshape(shp)
        mu = xg.mean(-1, keepdims=True)
        var = ((xg - mu) ** 2).mean(-1, keepdims=True)
        xf = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(xf.shape)
        out = xf * p["scale"] + p.get("bias", 0.0)
        return out.astype(dt)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    elif kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(kind)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    t = jax.random.normal(key, (vocab, d), jnp.float32) * (1.0 / math.sqrt(d))
    return {"table": t.astype(dtype)}


def embed_spec() -> dict:
    return {"table": P(MODEL, DATA)}


# ---------------------------------------------------------------------------
# rotary position embeddings (partial-fraction support for stablelm)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    return rot_dim, inv                      # (rot_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, head_dim: int,
               fraction: float, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    rot_dim, inv = rope_freqs(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv     # (B,S,R/2)
    cos = jnp.cos(ang)[..., None, :]                          # (B,S,1,R/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# activations / misc
# ---------------------------------------------------------------------------

ACT_FNS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x


def add_leading_none(spec_tree):
    """Prepend a None (stacked-layer) axis to every PartitionSpec leaf."""
    return jax.tree.map(lambda s: P(None, *s),
                        spec_tree,
                        is_leaf=lambda s: isinstance(s, P))

"""Mamba (S6) selective-state-space mixer — Jamba's dominant layer type.

TPU adaptation (DESIGN.md §2/§4): the CUDA selective-scan becomes a
*chunked associative scan* — within a chunk of ``cfg.mamba_chunk`` tokens
the recurrence h_t = dA_t h_{t-1} + dBx_t runs as a log-depth
``associative_scan`` on (B, c, D, N) tiles that fit VMEM-scale working
sets; chunks are threaded by a ``lax.scan`` carrying only the (B, D, N)
boundary state, so the (B, S, D, N) tensor never materializes (at jamba
train scale that tensor would be ~0.5 PB).

The selective scan itself stays bf16/f32 — a data-dependent multiplicative
recurrence is not an accumulate->monotone-activate pattern, so the paper's
BSN/SI does not apply here (DESIGN.md §4); the four projections around it
are SC-quantized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .common import DATA, MODEL, dense_apply, dense_init, dense_spec

__all__ = ["mamba_init", "mamba_spec", "mamba_train", "mamba_decode",
           "mamba_prefill_chunk", "mamba_state_init"]


def mamba_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, din, n, r = (cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state,
                    cfg.dt_rank)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    q = cfg.quant
    # S4D-real init for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * din, q, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (din, cfg.mamba_d_conv),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "x_proj": dense_init(ks[2], din, r + 2 * n, q, dtype=dtype),
        "dt_proj": dense_init(ks[3], r, din, q, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (din,)) * 0.1, 1e-3, None))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[5], din, d, q, dtype=dtype),
    }


def mamba_spec(cfg: ModelConfig) -> dict:
    q = cfg.quant
    return {
        "in_proj": dense_spec(DATA, MODEL, q),
        "conv_w": P(MODEL, None),
        "conv_b": P(MODEL),
        "x_proj": dense_spec(MODEL, None, q),
        "dt_proj": dense_spec(None, MODEL, q),
        "dt_bias": P(MODEL),
        "a_log": P(MODEL, None),
        "d_skip": P(MODEL),
        "out_proj": dense_spec(MODEL, DATA, q),
    }


def _split_xz(p, u, cfg):
    xz = dense_apply(p["in_proj"], u, cfg.quant)
    din = cfg.mamba_d_inner
    return xz[..., :din], xz[..., din:]


def _ssm_params(p, x, cfg):
    """x: (..., din) -> dt (..., din), B (..., N), C (..., N)."""
    n, r = cfg.mamba_d_state, cfg.dt_rank
    dbc = dense_apply(p["x_proj"], x, cfg.quant)
    dt_r, bm, cm = (dbc[..., :r], dbc[..., r:r + n], dbc[..., r + n:])
    dt = jax.nn.softplus(
        dense_apply(p["dt_proj"], dt_r, cfg.quant).astype(jnp.float32)
        + p["dt_bias"])
    return dt, bm.astype(jnp.float32), cm.astype(jnp.float32)


def _conv_full(p, x, cfg):
    """Causal depthwise conv over (B, S, din) as k weighted shifts."""
    k = cfg.mamba_d_conv
    w = p["conv_w"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    S = xf.shape[1]
    out = xf * w[:, k - 1]
    for i in range(1, k):
        # pad-then-crop keeps the shape right even when S < i
        shifted = jnp.pad(xf, ((0, 0), (i, 0), (0, 0)))[:, :S, :]
        out = out + shifted * w[:, k - 1 - i]
    return (out + p["conv_b"]).astype(x.dtype)


def _conv_window(p, xcat, cfg):
    """Causal depthwise conv over a chunk WITH its left context.

    xcat: (B, (k-1) + C, din) — the carried conv tail (k-1 pre-conv
    inputs, zeros at sequence start) concatenated before the chunk's
    pre-conv inputs.  Returns (B, C, din).  Term order matches
    :func:`_conv_full` exactly, so a zero tail reproduces its
    zero-padded output bit for bit.
    """
    k = cfg.mamba_d_conv
    w = p["conv_w"].astype(jnp.float32)
    xf = xcat.astype(jnp.float32)
    C = xf.shape[1] - (k - 1)
    out = xf[:, k - 1:] * w[:, k - 1]
    for i in range(1, k):
        out = out + xf[:, k - 1 - i:k - 1 - i + C] * w[:, k - 1 - i]
    return (out + p["conv_b"]).astype(xcat.dtype)


def _assoc_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, b1 * a2 + b2


def mamba_train(p: dict, u: jax.Array, cfg: ModelConfig):
    """u: (B, S, D) -> (y, (h_final, conv_tail)) for prefill caching."""
    B, S, _ = u.shape
    din, n = cfg.mamba_d_inner, cfg.mamba_d_state
    c = min(cfg.mamba_chunk, S)
    while S % c:
        c -= 1
    nc = S // c

    x_raw, z = _split_xz(p, u, cfg)
    x = _conv_full(p, x_raw, cfg)
    x = jax.nn.silu(x)
    dt, bm, cm = _ssm_params(p, x, cfg)
    a = -jnp.exp(p["a_log"])                              # (din, n)

    xf = x.astype(jnp.float32)
    # chunked scan: xs time-major over chunks
    def chunk_step(h0, inp):
        xc, dtc, bc, cc = inp                             # (B,c,din),(B,c,n)..
        da = jnp.exp(dtc[..., None] * a)                  # (B,c,din,n)
        dbx = (dtc * xc)[..., None] * bc[:, :, None, :]   # (B,c,din,n)
        pa, hs = jax.lax.associative_scan(_assoc_combine, (da, dbx), axis=1)
        hs = hs + pa * h0[:, None]                        # include carry-in
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)
        return hs[:, -1], y

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, c, *t.shape[2:]), 1, 0)

    h0 = jnp.zeros((B, din, n), jnp.float32)
    hT, ys = jax.lax.scan(chunk_step, h0,
                          (to_chunks(xf), to_chunks(dt), to_chunks(bm),
                           to_chunks(cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, din)
    y = y + xf * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    out = dense_apply(p["out_proj"], y, cfg.quant)
    # decode cache: final SSM state + the last (k-1) *pre-conv* inputs
    # (left-zero-padded when the prompt is shorter than the conv window)
    kc = cfg.mamba_d_conv - 1
    conv_tail = x_raw[:, max(S - kc, 0):, :]
    if S < kc:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (kc - S, 0), (0, 0)))
    return out, (hT, conv_tail)


def mamba_prefill_chunk(p: dict, u: jax.Array, cfg: ModelConfig,
                        state: dict, valid: jax.Array | None = None):
    """Chunk-resumable prefill: one chunk of the prompt through the
    per-token recurrence, consuming and emitting decode-shaped state.

    u: (B, C, D); state: ``{"h": (B, din, n) f32, "conv": (B, k-1, din)}``
    (zeros at sequence start — the same shapes :func:`mamba_decode`
    carries); ``valid``: optional (B, C) bool, True on real prompt
    tokens.  Masked positions leave the state untouched (``where`` on
    the carry is an exact select), so right-padded lanes in a batched
    prefill bucket freeze at their last real token.

    The scan is PER-TOKEN (not the train path's chunked associative
    scan): splitting a prompt at any boundary and threading the state
    replays the identical per-step ops, so chunked prefill is
    bit-identical to one-shot prefill for every chunk size — the
    order-exactness the serving differentials (batched == sequential on
    sc_int) stand on.  Training keeps :func:`mamba_train`'s log-depth
    associative scan; this path trades that depth for exactness, which
    is the right trade at serving prompt lengths.
    """
    B, C, _ = u.shape
    k = cfg.mamba_d_conv
    x_raw, z = _split_xz(p, u, cfg)
    xcat = jnp.concatenate([state["conv"].astype(x_raw.dtype), x_raw],
                           axis=1)
    x = jax.nn.silu(_conv_window(p, xcat, cfg))
    dt, bm, cm = _ssm_params(p, x, cfg)
    a = -jnp.exp(p["a_log"])                              # (din, n)
    xf = x.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct, mt = inp                         # (B,din),(B,n),(B,)
        da = jnp.exp(dtt[..., None] * a)                  # (B,din,n)
        hn = h * da + (dtt * xt)[..., None] * bt[:, None, :]
        hn = jnp.where(mt[:, None, None], hn, h)
        y = jnp.einsum("bdn,bn->bd", hn, ct)
        return hn, y

    vmask = jnp.ones((B, C), bool) if valid is None else valid
    tm = lambda t: jnp.moveaxis(t, 1, 0)                  # time-major
    hT, ys = jax.lax.scan(step, state["h"],
                          (tm(xf), tm(dt), tm(bm), tm(cm), tm(vmask)))
    y = jnp.moveaxis(ys, 0, 1)
    y = y + xf * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    out = dense_apply(p["out_proj"], y, cfg.quant)
    # conv tail: the k-1 pre-conv inputs ENDING at each lane's last valid
    # token.  xcat positions [nvalid, nvalid + k - 1) are exactly those
    # rows (old tail when nvalid == 0), so a gather both advances and
    # freezes correctly — no second masking pass.
    nvalid = jnp.sum(vmask, axis=1).astype(jnp.int32)     # (B,)
    idx = nvalid[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None, :]
    new_tail = jnp.take_along_axis(xcat, idx[:, :, None], axis=1)
    return out, {"h": hT, "conv": new_tail.astype(state["conv"].dtype)}


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    din, n, k = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {"h": jnp.zeros((batch, din, n), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, din), dtype)}


def mamba_decode(p: dict, u: jax.Array, cfg: ModelConfig, state: dict):
    """u: (B, 1, D); state {"h": (B,din,n), "conv": (B,k-1,din)}."""
    B = u.shape[0]
    k = cfg.mamba_d_conv
    x, z = _split_xz(p, u, cfg)                           # (B,1,din)
    x1 = x[:, 0, :]
    w = p["conv_w"].astype(jnp.float32)
    conv = state["conv"].astype(jnp.float32)
    xc = x1.astype(jnp.float32) * w[:, k - 1] + p["conv_b"]
    for i in range(1, k):
        xc = xc + conv[:, k - 1 - i, :] * w[:, k - 1 - i]
    xc = jax.nn.silu(xc)
    dt, bm, cm = _ssm_params(p, xc.astype(u.dtype)[:, None, :], cfg)
    dt, bm, cm = dt[:, 0], bm[:, 0], cm[:, 0]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)                       # (B,din,n)
    h = state["h"] * da + (dt * xc)[..., None] * bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cm) + xc * p["d_skip"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(u.dtype)
    out = dense_apply(p["out_proj"], y[:, None, :], cfg.quant)
    new_conv = jnp.concatenate([state["conv"][:, 1:], x], axis=1)
    return out, {"h": h, "conv": new_conv}

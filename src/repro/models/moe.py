"""Mixture-of-Experts with GShard-style grouped one-hot dispatch.

Expert-parallel design (DESIGN.md §5): tokens are split into groups
(sharded over batch/"data"), experts over "model".  The dispatch/combine
einsums contract a (G, S_g, E, C) one-hot against activations, which GSPMD
lowers to the canonical all-to-all pair around the expert FFNs.  Capacity
is per-group (``C = ceil(k * S_g * cf / E)``); overflowing tokens drop to
the residual path (standard GShard semantics, capacity_factor configurable
per arch).

The expert FFN matmuls route through the same SC quantization as dense
layers — MoE expert weights are the paper technique's richest target
(qwen3: 87% of active params live here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sc_layers import SCQuantConfig
from repro.core.quant import ternary_weight_quant, thermometer_act_quant
from repro.distributed.sharding import constrain
from jax.sharding import PartitionSpec as P

from .common import ACT_FNS, DATA, MODEL

__all__ = ["moe_init", "moe_spec", "moe_apply"]


def _expert_dense_init(key, e, d_in, d_out, quant: SCQuantConfig, dtype):
    import math
    std = 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * std)
    p = {"w": w.astype(dtype)}
    if quant.enabled:
        p["alpha_w"] = jnp.full((e, 1, d_out) if quant.per_channel else (e,),
                                1.4 * std * 0.8, jnp.float32)
        p["alpha_a"] = jnp.asarray(2.0 / math.sqrt(max(quant.act_half, 1)),
                                   jnp.float32)
    return p


def _expert_dense_spec(quant: SCQuantConfig, in_axis, out_axis):
    s = {"w": P(MODEL, in_axis, out_axis)}
    if quant.enabled:
        s["alpha_w"] = P(MODEL, None, out_axis) if quant.per_channel \
            else P(MODEL)
        s["alpha_a"] = P()
    return s


def _expert_matmul(p: dict, x: jax.Array, quant: SCQuantConfig,
                   spec: str) -> jax.Array:
    """einsum(spec) on the expert weights, routed through the same SC
    quantization discipline as dense layers (common.dense_apply)."""
    w = p["w"]
    if quant.enabled and quant.mode == "sc_qat":
        # bf16-native fake-quant (see common.dense_apply / quant.py)
        x = thermometer_act_quant(x, p["alpha_a"], quant.act_bsl)
        w = ternary_weight_quant(w, p["alpha_w"]).astype(x.dtype)
    elif quant.enabled and quant.mode == "sc_int":
        # Integer serving datapath, mirroring sc_linear_int_from_qat:
        # int8 levels x ternary weights -> exact int32 accumulation,
        # rescaled to the float residual stream.  Experts previously ran
        # the raw UNQUANTIZED float einsum under sc_int/sc_int_approx —
        # the precision leak the dtype-purity gate
        # (analysis/contracts.py) exists to catch.  The approximate-BSN
        # engine keeps the exact int32 accumulator here: the grouped
        # (E,G,C) expert layout has no approx-adder kernel path yet
        # (tracked in analysis/README.md).
        half = quant.act_half
        aa = p["alpha_a"].astype(x.dtype)
        aw = p["alpha_w"].astype(jnp.float32)
        x_q = jnp.clip(jnp.round(x / aa), -half, half).astype(jnp.int8)
        aw_b = aw if aw.ndim > 1 else aw[:, None, None]
        w_int = jnp.clip(jnp.round(w.astype(jnp.float32) / aw_b), -1, 1
                         ).astype(jnp.int8)
        sum_q = jnp.einsum(spec, x_q.astype(jnp.int32),
                           w_int.astype(jnp.int32))
        scale = aa.astype(jnp.float32) * aw       # (E,1,d_out) or (E,)
        scale = scale[:, None, None, None] if scale.ndim == 1 \
            else scale[:, None]                   # -> (E,1,1,[d_out])
        return (sum_q.astype(jnp.float32) * scale).astype(x.dtype)
    return jnp.einsum(spec, x, w)


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        "w_up": _expert_dense_init(ks[1], e, d, f, cfg.quant, dtype),
        "w_down": _expert_dense_init(ks[2], e, f, d, cfg.quant, dtype),
    }
    if cfg.ffn_gated:
        p["w_gate"] = _expert_dense_init(ks[3], e, d, f, cfg.quant, dtype)
    return p


def moe_spec(cfg: ModelConfig, serving: bool = False) -> dict:
    """Expert weight sharding: (E:model, d_model:data) for training (ZeRO
    over the contraction dim — gathers amortize over the token batch), but
    (E:model, d_ff:data) for serving: decode is weight-traffic-bound, so
    the weights stay resident and only the (tiny) expert activations
    all-reduce over data (§Perf iteration: qwen3 decode_32k).

    The serving layout also satisfies the SC-datapath correctness
    constraint the mesh-sharded ServeEngine relies on: experts are whole
    per device (the expert matmul contractions d/f stay local), so each
    output channel's BSN accumulation — exact or approximate — never
    splits across chips.  The only cross-device float reduction left is
    the router-weighted combine over E, which is outside the quantized
    datapath."""
    q = cfg.quant
    in_ax, out_ax = (None, DATA) if serving else (DATA, None)
    s = {
        "router": P(None, None),
        "w_up": _expert_dense_spec(q, in_ax, out_ax),
        "w_down": _expert_dense_spec(q, out_ax, in_ax),
    }
    if cfg.ffn_gated:
        s["w_gate"] = _expert_dense_spec(q, in_ax, out_ax)
    return s


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss). Grouped dispatch as per module doc."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    sg = min(cfg.moe_group_size, B * S)
    assert (B * S) % sg == 0, (B, S, sg)
    G = (B * S) // sg
    cap = int(-(-k * sg * cfg.moe_capacity_factor // E))
    cap = max(4, -(-cap // 4) * 4)                     # pad to multiple of 4

    xt = x.reshape(G, sg, D)
    gate_logits = (xt.astype(jnp.float32) @ p["router"])      # (G,sg,E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                    # (G,sg,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue (token-major)
    mask = jax.nn.one_hot(top_i, E, dtype=jnp.float32)        # (G,sg,k,E)
    mask_flat = mask.reshape(G, sg * k, E)
    pos_flat = (jnp.cumsum(mask_flat, axis=1) - 1.0) * mask_flat
    pos = pos_flat.sum(-1).reshape(G, sg, k).astype(jnp.int32)  # (G,sg,k)
    keep = (pos < cap) & (top_w > 0)

    # dispatch (0/1) and combine (router-weighted) tensors: (G,sg,E,C)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) \
        * keep[..., None].astype(jnp.float32)                 # (G,sg,k,C)
    disp = jnp.einsum("gske,gskc->gsec", mask, pos_oh)
    comb = jnp.einsum("gske,gskc->gsec", mask * top_w[..., None], pos_oh)
    disp = constrain(disp.astype(x.dtype), "batch", None, "expert", None)

    # decode (S==1): the token set is tiny — replicate it across "data" so
    # the resident (d_ff:data)-sharded expert weights never gather
    g_axis = None if S == 1 else "batch"

    # all-to-all in: (E, G, C, D)
    ein = jnp.einsum("gsec,gsd->egcd", disp, xt)
    ein = constrain(ein, "expert", g_axis, None, None)

    act = ACT_FNS[cfg.ffn_act]
    if cfg.ffn_gated:
        h = act(_expert_matmul(p["w_gate"], ein, cfg.quant, "egcd,edf->egcf")) \
            * _expert_matmul(p["w_up"], ein, cfg.quant, "egcd,edf->egcf")
    else:
        h = act(_expert_matmul(p["w_up"], ein, cfg.quant, "egcd,edf->egcf"))
    eout = _expert_matmul(p["w_down"], h, cfg.quant, "egcf,efd->egcd")
    eout = constrain(eout, "expert", g_axis, None, None)

    # all-to-all out + weighted combine
    y = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), eout)
    y = y.reshape(B, S, D)

    # Switch-style load-balance aux loss + router z-loss
    density = mask.sum(2).mean(1)                              # (G,E) frac
    p_mean = probs.mean(1)                                     # (G,E)
    aux = E * jnp.mean(jnp.sum(density * p_mean, axis=-1))
    zloss = jnp.mean(jax.scipy.special.logsumexp(gate_logits, -1) ** 2)
    return y, aux + 1e-3 * zloss

"""train_step builder: loss -> grads -> clip -> (compress) -> AdamW.

This is the function the dry-run lowers for the ``train_*`` cells.  Grad
accumulation (microbatching) runs as a ``lax.scan`` over microbatch slices
so the lowered HLO is identical in structure at any accumulation factor.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.compression import compress_decompress, init_error_state
from repro.models import loss_fn
from repro.optim import adamw_init, adamw_update, clip_by_global_norm

__all__ = ["TrainState", "init_train_state", "build_train_step"]


class TrainState(NamedTuple):
    params: dict
    opt: dict
    step: jax.Array
    error: dict | None = None       # grad-compression error feedback


def init_train_state(params, cfg: ModelConfig,
                     grad_compress: bool = False) -> TrainState:
    opt = adamw_init(params, cfg.opt_state_dtype)
    err = init_error_state(params) if grad_compress else None
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), error=err)


def build_train_step(cfg: ModelConfig,
                     lr_schedule: Callable,
                     grad_accum: int = 1,
                     max_grad_norm: float = 1.0,
                     grad_compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def forward_loss(params, batch):
        return loss_fn(params, batch, cfg)

    def train_step(state: TrainState, batch: dict):
        params = state.params

        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                forward_loss, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(forward_loss,
                                               has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = {"loss": loss}

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        error = state.error
        if grad_compress and error is not None:
            grads, error = compress_decompress(grads, error)

        lr = lr_schedule(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr,
                       step=state.step.astype(jnp.float32))
        return TrainState(new_params, new_opt, state.step + 1, error), metrics

    return train_step

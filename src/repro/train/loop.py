"""Fault-tolerant training loop.

Large-scale posture (DESIGN.md §5):
* resume-from-latest on start (checkpoint/restart);
* periodic async checkpoints + save-on-SIGTERM (preemption safety);
* per-step heartbeat with wall-time — the launcher-side straggler signal
  (a rank whose heartbeat lags the fleet median is the restart candidate);
* stateless data (batch = f(step)) so restart/rescale replays nothing.
"""

from __future__ import annotations

import signal
import time
from typing import Callable

import jax

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, wait_for_saves)

__all__ = ["run_training"]


def run_training(train_step: Callable, state, batch_fn: Callable,
                 n_steps: int, ckpt_dir: str | None = None,
                 ckpt_every: int = 100, log_every: int = 10,
                 log_fn: Callable = print, shardings=None):
    """Run ``n_steps`` of training with checkpoint/restart.

    ``batch_fn(step) -> batch`` must be stateless (see module docstring).
    Returns the final state and the metrics history.
    """
    start = 0
    if ckpt_dir is not None:
        last = latest_step(ckpt_dir)
        if last is not None:
            state = restore_checkpoint(ckpt_dir, last, state, shardings)
            start = int(last)
            log_fn(f"[loop] resumed from checkpoint step {start}")

    stop = {"flag": False}

    def _on_term(signum, frame):
        stop["flag"] = True

    prev = signal.signal(signal.SIGTERM, _on_term)
    history = []
    t_last = time.monotonic()
    try:
        for step in range(start, n_steps):
            batch = batch_fn(step)
            state, metrics = train_step(state, batch)
            if (step + 1) % log_every == 0 or step == n_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                now = time.monotonic()
                m["sec_per_step"] = (now - t_last) / log_every
                t_last = now
                history.append({"step": step + 1, **m})
                log_fn(f"[loop] step {step + 1} " +
                       " ".join(f"{k}={v:.4g}" for k, v in m.items()))
            want_ckpt = ckpt_dir is not None and (
                (step + 1) % ckpt_every == 0 or stop["flag"]
                or step == n_steps - 1)
            if want_ckpt:
                jax.block_until_ready(state.params)
                save_checkpoint(ckpt_dir, step + 1, state)
            if stop["flag"]:
                log_fn(f"[loop] SIGTERM: checkpointed at {step + 1}, exiting")
                break
    finally:
        wait_for_saves()
        signal.signal(signal.SIGTERM, prev)
    return state, history

"""Training substrate: step builder + fault-tolerant loop."""

from .step import TrainState, build_train_step, init_train_state
from .loop import run_training

__all__ = ["TrainState", "build_train_step", "init_train_state",
           "run_training"]

"""Bitonic Sorting Network non-linear adder (paper §II-B, §IV).

Exact path (Fig 3b): concatenate the thermometer bitstreams of all addends
and bitonic-sort them.  The sorted vector is a thermometer code whose
popcount is the exact sum of input popcounts, so the accumulated value is
``sum_q = popcount(sorted) - (N*L)/2``.

Approximate spatial path (Fig 10b): a parameterized progressive-sorting
pipeline.  Stage ``i`` groups ``g_i`` partial codes, sorts them, then
*sub-samples*: clip ``c_i`` bits off each end (inputs are near-Gaussian, the
tails carry almost no mass — Fig 11), keep one of every ``s_i`` bits.  Each
surviving bit then represents ``s_i`` units of the original scale, so the
overall output scale is ``prod(s_i)`` (a power of two, realigned by the
residual re-scaling block of §III-C).

Temporal path (Fig 12): a physically small BSN is reused over ``T`` cycles
to cover a ``T``-times-wider accumulation; functionally a chunked reduce
with the spatial pipeline applied per cycle.

Everything exists three times, in decreasing order of fidelity and
increasing order of speed:

* ``*_bits``   — bit-exact circuit simulation (compare-exchange network on
  the actual bit vectors).  Used by fault-injection and MSE experiments.
* ``*_counts`` — the TPU-native functional equivalent on popcounts.  The
  bit/count equivalence is proven in tests/test_bsn.py; the count path is
  the ORACLE for the kernels.
* the fused Pallas kernels (kernels/approx_bsn.py) — the deployable hot
  path: the whole progressive pipeline in one VMEM-resident pass, plus
  the chunked temporal-reuse variant.  Proven equal to ``*_counts`` (and
  transitively to the circuit) in tests/test_approx_bsn_kernel.py.

:func:`approx_bsn` below is the front door: it routes through the kernel
dispatch layer (kernels/dispatch.py) which picks compiled pallas on TPU,
the interpreter elsewhere, and the count reference for tiny shapes — so
SC layers and the serving path hit the kernel by default without naming
it.  :func:`default_approx_spec` designs a sensible spec for a given
accumulation width when the caller doesn't carry one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

__all__ = [
    "bitonic_sort",
    "exact_bsn_bits",
    "exact_bsn_counts",
    "SubSampleSpec",
    "StageSpec",
    "ApproxBSNSpec",
    "approx_bsn_counts",
    "approx_bsn_bits",
    "approx_bsn_output_bsl",
    "approx_bsn_scale",
    "spatial_temporal_counts",
    "approx_bsn",
    "default_approx_spec",
]


# ---------------------------------------------------------------------------
# bitonic sort (Batcher 1968) — vectorized compare-exchange network
# ---------------------------------------------------------------------------

def _ceil_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def bitonic_sort(x: jax.Array, descending: bool = True) -> jax.Array:
    """Sort the trailing axis with Batcher's bitonic network.

    Works on any dtype supporting min/max. Non-power-of-two lengths are
    padded with sentinels and cropped (hardware pads with constant bits).
    The stage structure mirrors the circuit exactly: ``log2(n)`` merge
    phases of ``1..log2(n)`` compare-exchange levels, each level a fully
    parallel bank of comparators (AND/OR pairs for 1-bit inputs).
    """
    n = x.shape[-1]
    m = _ceil_pow2(n)
    if m != n:
        pad_val = jnp.array(jnp.iinfo(x.dtype).min if descending
                            else jnp.iinfo(x.dtype).max, dtype=x.dtype) \
            if jnp.issubdtype(x.dtype, jnp.integer) else \
            jnp.array(-jnp.inf if descending else jnp.inf, dtype=x.dtype)
        pad = jnp.broadcast_to(pad_val, x.shape[:-1] + (m - n,))
        x = jnp.concatenate([x, pad], axis=-1)

    idx = jnp.arange(m)
    for k_bit in range(1, m.bit_length()):            # merge phase size 2^k
        k = 1 << k_bit
        for j_bit in range(k_bit - 1, -1, -1):        # exchange distance 2^j
            j = 1 << j_bit
            partner = idx ^ j
            lo = jnp.minimum(idx, partner)
            a = x[..., lo]
            b = x[..., lo ^ j]
            up = (idx & k) == 0                       # direction per block
            if descending:
                keep_hi = up
            else:
                keep_hi = ~up
            hi_v = jnp.maximum(a, b)
            lo_v = jnp.minimum(a, b)
            first = jnp.where(keep_hi, hi_v, lo_v)    # value at position lo
            second = jnp.where(keep_hi, lo_v, hi_v)   # value at position lo^j
            x = jnp.where((idx & j) == 0, first, second)
    return x[..., :n]


def exact_bsn_bits(bits: jax.Array) -> jax.Array:
    """Exact BSN: ``(..., N, L)`` thermometer codes -> ``(..., N*L)`` sorted.

    The output is again a thermometer code (descending sort of 0/1 bits)
    representing the exact sum.
    """
    flat = bits.reshape(bits.shape[:-2] + (bits.shape[-2] * bits.shape[-1],))
    return bitonic_sort(flat.astype(jnp.int8), descending=True)


def exact_bsn_counts(counts: jax.Array, axis: int = -1) -> jax.Array:
    """Functional equivalent: the sorted popcount is just the sum."""
    return jnp.sum(counts.astype(jnp.int32), axis=axis)


# ---------------------------------------------------------------------------
# approximate spatial BSN (paper §IV-B, Fig 10b)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SubSampleSpec:
    """Truncated-quantization sub-sampler inside one sub-BSN.

    clip ``clip`` bits from *each* end of the sorted code, then keep one of
    every ``stride`` bits (phase picks which of the ``stride`` wires is
    tapped; ``stride//2`` centers the quantizer).
    """
    clip: int = 0
    stride: int = 1

    def out_len(self, in_len: int) -> int:
        kept = in_len - 2 * self.clip
        if kept <= 0 or kept % self.stride != 0:
            raise ValueError(
                f"sub-sample (clip={self.clip}, stride={self.stride}) "
                f"invalid for BSL {in_len}")
        return kept // self.stride

    @property
    def phase(self) -> int:
        return self.stride // 2

    def apply_counts(self, c: jax.Array, in_len: int) -> jax.Array:
        """Count-domain semantics: saturate then floor-divide with phase."""
        kept = in_len - 2 * self.clip
        c = jnp.clip(c - self.clip, 0, kept)
        return (c + self.phase) // self.stride

    def apply_bits(self, sorted_bits: jax.Array) -> jax.Array:
        """Bit-domain semantics: literally tap wires of the sorted vector."""
        in_len = sorted_bits.shape[-1]
        out_len = self.out_len(in_len)
        # output bit j taps sorted position clip + j*stride + (stride-1-phase)
        pos = self.clip + jnp.arange(out_len) * self.stride \
            + (self.stride - 1 - self.phase)
        return sorted_bits[..., pos]


@dataclass(frozen=True)
class StageSpec:
    """One progressive-sorting stage: group ``group`` codes, sort, sample."""
    group: int
    sub: SubSampleSpec = field(default_factory=SubSampleSpec)


@dataclass(frozen=True)
class ApproxBSNSpec:
    """Parameterized BSN design space (paper Fig 10b).

    ``in_bsl``: BSL of each of the ``width`` input codes.
    ``stages``: progressive stages; ``prod(group_i)`` must equal ``width``.
    """
    width: int
    in_bsl: int
    stages: tuple[StageSpec, ...]

    def __post_init__(self):
        g = math.prod(s.group for s in self.stages)
        if g != self.width:
            raise ValueError(f"prod(groups)={g} != width={self.width}")
        self.layer_bsls()  # validates divisibility

    def layer_bsls(self) -> list[int]:
        """BSL entering each stage (and the final output BSL last)."""
        bsls = [self.in_bsl]
        for s in self.stages:
            sorted_len = bsls[-1] * s.group
            bsls.append(s.sub.out_len(sorted_len))
        return bsls

    @property
    def out_bsl(self) -> int:
        return self.layer_bsls()[-1]

    @property
    def scale(self) -> int:
        """Units-per-bit of the output relative to the input (prod strides)."""
        return math.prod(s.sub.stride for s in self.stages)


def approx_bsn_output_bsl(spec: ApproxBSNSpec) -> int:
    return spec.out_bsl


def approx_bsn_scale(spec: ApproxBSNSpec) -> int:
    return spec.scale


def approx_bsn_counts(counts: jax.Array, spec: ApproxBSNSpec) -> jax.Array:
    """Count-domain approximate BSN.

    ``counts``: ``(..., width)`` popcounts of the input codes (each in
    ``[0, in_bsl]``).  Returns the output code's popcount in
    ``[0, out_bsl]``; the represented q value is
    ``scale * (out_count - out_bsl/2)``.
    """
    if counts.shape[-1] != spec.width:
        raise ValueError(f"expected width {spec.width}, got {counts.shape}")
    c = counts.astype(jnp.int32)
    bsl = spec.in_bsl
    for s in spec.stages:
        c = c.reshape(c.shape[:-1] + (c.shape[-1] // s.group, s.group))
        c = jnp.sum(c, axis=-1)                       # sorted popcount
        sorted_len = bsl * s.group
        c = s.sub.apply_counts(c, sorted_len)
        bsl = s.sub.out_len(sorted_len)
    return jnp.squeeze(c, axis=-1)


def approx_bsn_bits(bits: jax.Array, spec: ApproxBSNSpec) -> jax.Array:
    """Bit-exact approximate BSN on ``(..., width, in_bsl)`` codes."""
    if bits.shape[-2] != spec.width or bits.shape[-1] != spec.in_bsl:
        raise ValueError(f"expected (..., {spec.width}, {spec.in_bsl}), "
                         f"got {bits.shape}")
    x = bits
    for s in spec.stages:
        m = x.shape[-2] // s.group
        x = x.reshape(x.shape[:-2] + (m, s.group * x.shape[-1]))
        x = bitonic_sort(x.astype(jnp.int8), descending=True)
        x = s.sub.apply_bits(x)
    return jnp.squeeze(x, axis=-2)


# ---------------------------------------------------------------------------
# spatial-temporal BSN (paper §IV-B, Fig 12)
# ---------------------------------------------------------------------------

def spatial_temporal_counts(counts: jax.Array, spec: ApproxBSNSpec,
                            cycles: int) -> jax.Array:
    """Fold a ``cycles * spec.width`` accumulation onto one small BSN.

    Input ``(..., cycles * width)`` popcounts. Each cycle runs the spatial
    pipeline on its chunk; the compressed partial sums (already short codes)
    are accumulated exactly by a final small adder. Output is in *output
    scale units* of the spatial spec: value = scale*(out - cycles*out_bsl/2).
    """
    w = spec.width
    if counts.shape[-1] != cycles * w:
        raise ValueError(f"expected {cycles * w} inputs, got {counts.shape}")
    c = counts.reshape(counts.shape[:-1] + (cycles, w))
    partial = approx_bsn_counts(c, spec)              # (..., cycles)
    return jnp.sum(partial, axis=-1)


# ---------------------------------------------------------------------------
# kernel front door
# ---------------------------------------------------------------------------

def approx_bsn(counts: jax.Array, spec: ApproxBSNSpec, *, cycles: int = 1,
               backend: str | None = None, **kw) -> jax.Array:
    """Run the approximate adder through the kernel dispatch layer.

    Semantics of :func:`approx_bsn_counts` (``cycles == 1``) or
    :func:`spatial_temporal_counts` (``cycles > 1``), executed by the
    fused Pallas kernel whenever the backend/shape warrants it — see
    kernels/dispatch.py for the selection policy and ``backend=`` /
    ``kernels.dispatch.backend_scope`` for overrides.
    """
    from repro.kernels import dispatch                # lazy: core <- kernels
    return dispatch.approx_bsn(counts, spec, cycles=cycles, backend=backend,
                               **kw)


def default_approx_spec(width: int, in_bsl: int, *,
                        target_out_bsl: int = 32) -> ApproxBSNSpec:
    """Design a single-stage spec for a ``width``-wide accumulation.

    Picks a power-of-two stride (re-alignable by the §III-C residual
    re-scaler) so the output BSL lands near ``target_out_bsl``, then a
    symmetric clip window absorbing the rest of the sorted length.  The
    3-sigma check of Fig 11 is the caller's job — this is the shape
    recipe, tightened per layer by the bench_approx_bsn sweep.
    """
    sorted_len = width * in_bsl
    if sorted_len <= target_out_bsl:
        return ApproxBSNSpec(width=width, in_bsl=in_bsl,
                             stages=(StageSpec(width, SubSampleSpec(0, 1)),))
    stride = 1
    while stride * 2 * target_out_bsl <= sorted_len:
        stride *= 2
    # symmetric clipping needs kept == sorted_len (mod 2); an even stride
    # makes kept even, so an odd sorted length forces stride 1
    if sorted_len % 2 and stride > 1:
        stride = 1
    out_bsl = min(target_out_bsl, sorted_len // stride)
    if (sorted_len - out_bsl * stride) % 2:     # only possible at stride 1
        out_bsl += 1 if out_bsl + 1 <= sorted_len else -1
    kept = out_bsl * stride
    return ApproxBSNSpec(
        width=width, in_bsl=in_bsl,
        stages=(StageSpec(width, SubSampleSpec((sorted_len - kept) // 2,
                                               stride)),))

"""Bit-error fault injection (paper Fig 5).

Thermometer SC codes degrade gracefully under bit flips: a flipped bit
changes the popcount by exactly 1 LSB regardless of position.  Positional
binary is catastrophic: a flipped MSB changes the value by 2^(B-1).  The
paper reports ~70% lower accuracy loss under equal BER; we reproduce the
mechanism with both representations decoded back to values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .coding import counts_from_bits, encode_thermometer

__all__ = [
    "flip_bits",
    "thermometer_under_ber",
    "binary_under_ber",
]


def flip_bits(bits: jax.Array, ber: float, key: jax.Array) -> jax.Array:
    """XOR a Bernoulli(ber) mask into a {0,1} bit tensor."""
    mask = jax.random.bernoulli(key, ber, bits.shape)
    return jnp.bitwise_xor(bits.astype(jnp.int8), mask.astype(jnp.int8))


def thermometer_under_ber(x_q: jax.Array, bsl: int, ber: float,
                          key: jax.Array) -> jax.Array:
    """Encode q levels as thermometer, flip at BER, decode.

    Note the decode is popcount - L/2: flipped bits are +-1 LSB each, and
    flips in the 1-region and 0-region partially cancel.
    """
    bits = encode_thermometer(x_q, bsl)
    noisy = flip_bits(bits, ber, key)
    return counts_from_bits(noisy) - bsl // 2


def binary_under_ber(x_q: jax.Array, n_bits: int, ber: float,
                     key: jax.Array) -> jax.Array:
    """Two's-complement baseline: flip bits of the positional encoding.

    ``x_q`` in [-2^(B-1), 2^(B-1)-1]. A single MSB flip moves the value by
    2^(B-1) — the failure mode thermometer coding removes.
    """
    v = x_q.astype(jnp.int32) & ((1 << n_bits) - 1)   # two's complement field
    weights = (1 << jnp.arange(n_bits, dtype=jnp.int32))
    bits = ((v[..., None] // weights) % 2).astype(jnp.int8)
    noisy = flip_bits(bits, ber, key)
    nv = jnp.sum(noisy.astype(jnp.int32) * weights, axis=-1)
    # sign-extend
    sign = nv >= (1 << (n_bits - 1))
    return jnp.where(sign, nv - (1 << n_bits), nv)

"""Deterministic thermometer coding (paper §II, Table II).

A value ``x`` is represented as ``x = alpha * x_q`` where ``x_q`` is an
integer *level* in ``[-L/2, +L/2]`` (L+1 levels) and the bitstream is the
L-bit thermometer code with ``x_q + L/2`` ones followed by zeros::

    BSL=2 :  00 -> -1   10 -> 0   11 -> +1          (ternary)
    BSL=4 :  0000 -> -2 ... 1111 -> +2
    BSL=16:  levels -8..+8

Three value domains are used throughout the code base:

* **bit domain**   — int8 arrays with a trailing length-L axis of {0,1}.
* **q domain**     — integer levels ``x_q = popcount(bits) - L/2``.
* **count domain** — ``c = popcount(bits) = x_q + L/2 in [0, L]``.

The bit domain exists for bit-exact circuit simulation (fault injection,
sorting-network experiments); the q/count domains are the TPU-native
functional equivalents (popcount of a sorted thermometer code depends only
on the count, so every downstream circuit is a function of the count).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "check_bsl",
    "encode_thermometer",
    "decode_thermometer",
    "counts_from_bits",
    "negate_bits",
    "zero_code",
    "quantize_levels",
    "dequantize_levels",
    "is_thermometer",
    "THERMOMETER_TABLE",
]

# Table II of the paper, used directly by tests.
THERMOMETER_TABLE = {
    2: {-1: "00", 0: "10", 1: "11"},
    4: {-2: "0000", -1: "1000", 0: "1100", 1: "1110", 2: "1111"},
}


def check_bsl(bsl: int) -> int:
    """Validate a bitstream length: positive and even (zero must be exact)."""
    if bsl < 2 or bsl % 2 != 0:
        raise ValueError(f"BSL must be an even integer >= 2, got {bsl}")
    return bsl


def encode_thermometer(x_q: jax.Array, bsl: int) -> jax.Array:
    """q domain -> bit domain.

    ``x_q`` integer levels in [-bsl/2, bsl/2] (values outside are clipped,
    matching saturating hardware registers). Output int8 ``(..., bsl)``.
    """
    check_bsl(bsl)
    half = bsl // 2
    count = jnp.clip(x_q, -half, half).astype(jnp.int32) + half
    positions = jnp.arange(bsl, dtype=jnp.int32)
    return (positions < count[..., None]).astype(jnp.int8)


def counts_from_bits(bits: jax.Array) -> jax.Array:
    """bit domain -> count domain (popcount along the trailing axis)."""
    return jnp.sum(bits.astype(jnp.int32), axis=-1)


def decode_thermometer(bits: jax.Array) -> jax.Array:
    """bit domain -> q domain: ``popcount - L/2``."""
    bsl = bits.shape[-1]
    check_bsl(bsl)
    return counts_from_bits(bits) - bsl // 2


def negate_bits(bits: jax.Array) -> jax.Array:
    """Bit-domain negation: complement + reverse keeps thermometer form.

    popcount' = L - popcount  =>  x_q' = -x_q. In hardware this is free
    (wiring + inverters); here it is a flip + logical not.
    """
    return (1 - bits[..., ::-1]).astype(jnp.int8)


def zero_code(bsl: int, shape: tuple[int, ...] = ()) -> jax.Array:
    """The thermometer code of level 0 (L/2 ones then L/2 zeros)."""
    check_bsl(bsl)
    one = encode_thermometer(jnp.zeros(shape, jnp.int32), bsl)
    return one


def quantize_levels(x: jax.Array, alpha: jax.Array, bsl: int) -> jax.Array:
    """float -> q domain: ``clip(round(x / alpha), -L/2, L/2)``.

    This is the *inference-time* quantizer; the differentiable QAT version
    with learned-step-size gradients lives in :mod:`repro.core.quant`.
    """
    check_bsl(bsl)
    half = bsl // 2
    return jnp.clip(jnp.round(x / alpha), -half, half).astype(jnp.int32)


def dequantize_levels(x_q: jax.Array, alpha: jax.Array) -> jax.Array:
    """q domain -> float: ``alpha * x_q``."""
    return x_q.astype(jnp.float32) * alpha


def is_thermometer(bits: np.ndarray | jax.Array) -> np.ndarray:
    """True where the trailing axis is a valid thermometer code (1s first)."""
    b = np.asarray(bits)
    # once a 0 appears, no 1 may follow: cumulative min equals the sequence
    descending = np.all(b[..., :-1] >= b[..., 1:], axis=-1)
    binary = np.all((b == 0) | (b == 1), axis=-1)
    return descending & binary

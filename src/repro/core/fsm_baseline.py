"""FSM-based stochastic computing baseline (paper refs [6]-[9], Fig 1).

The designs the paper improves on: values are *stochastic* bipolar
bitstreams (P(bit=1) = (x+1)/2), multiplication is XNOR, accumulation is a
mux/adder tree, and activation functions are saturating-counter FSMs
processed serially over the stream:

* **Stanh** (Brown & Card): K-state up/down counter; output bit = 1 iff
  state >= K/2.  Approximates tanh(K*x/2) in expectation, with output
  variance that only decays as 1/sqrt(stream length) — hence the paper's
  Fig 1 observation that 1024-bit streams are still visibly wrong, and the
  latency argument for deterministic coding.
* **FSM ReLU** ([9]-style): same counter, but the output bit mirrors the
  input when the state is in the upper half (positive estimate) and
  emits the 0-code (alternating bits, bipolar zero) otherwise.

These run under ``jax.lax.scan`` (the serial FSM is inherently sequential —
that is the point the paper makes against it).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "stochastic_bitstream",
    "xnor_multiply",
    "fsm_stanh",
    "fsm_relu",
    "decode_bipolar",
]


def stochastic_bitstream(x: jax.Array, length: int, key: jax.Array) -> jax.Array:
    """Bipolar stochastic stream: bit_t ~ Bernoulli((x+1)/2), x in [-1,1].

    Shape: x (...,) -> (..., length), int8.
    """
    p = jnp.clip((x + 1.0) / 2.0, 0.0, 1.0)
    u = jax.random.uniform(key, x.shape + (length,))
    return (u < p[..., None]).astype(jnp.int8)


def decode_bipolar(bits: jax.Array) -> jax.Array:
    """E[x] estimate: 2*mean(bits) - 1."""
    return 2.0 * jnp.mean(bits.astype(jnp.float32), axis=-1) - 1.0


def xnor_multiply(a_bits: jax.Array, b_bits: jax.Array) -> jax.Array:
    """Bipolar SC multiply: XNOR of independent streams."""
    return (a_bits == b_bits).astype(jnp.int8)


@partial(jax.jit, static_argnames=("n_states",))
def fsm_stanh(bits: jax.Array, n_states: int = 8) -> jax.Array:
    """Stanh FSM over a (..., T) bipolar stream -> (..., T) output stream.

    state += bit ? +1 : -1, saturating in [0, n_states-1];
    out bit = state >= n_states/2. Approximates tanh(n_states/2 * x).
    """
    half = n_states // 2
    init = jnp.full(bits.shape[:-1], half, jnp.int32)

    def step(state, b):
        b = b.astype(jnp.int32)
        nstate = jnp.clip(state + 2 * b - 1, 0, n_states - 1)
        out = (nstate >= half).astype(jnp.int8)
        return nstate, out

    _, outs = jax.lax.scan(step, init, jnp.moveaxis(bits, -1, 0))
    return jnp.moveaxis(outs, 0, -1)


@partial(jax.jit, static_argnames=("n_states",))
def fsm_relu(bits: jax.Array, n_states: int = 8) -> jax.Array:
    """FSM-based ReLU ([9]): pass the input bit when the running estimate is
    positive, emit bipolar-zero (alternating 0/1) otherwise."""
    half = n_states // 2
    init_state = jnp.full(bits.shape[:-1], half, jnp.int32)
    init_tog = jnp.zeros(bits.shape[:-1], jnp.int32)

    def step(carry, b):
        state, toggle = carry
        bi = b.astype(jnp.int32)
        nstate = jnp.clip(state + 2 * bi - 1, 0, n_states - 1)
        zero_bit = toggle               # alternating 0,1,0,1 == bipolar 0
        out = jnp.where(nstate >= half, bi, zero_bit).astype(jnp.int8)
        return (nstate, 1 - toggle), out

    _, outs = jax.lax.scan(step, (init_state, init_tog),
                           jnp.moveaxis(bits, -1, 0))
    return jnp.moveaxis(outs, 0, -1)

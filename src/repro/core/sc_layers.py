"""SC-quantized layers: the paper's datapath as composable JAX modules.

Two execution modes per layer (selected by ``config.quant`` at the model
level):

* ``sc_qat``  — differentiable fake-quant training path: LSQ ternary
  weights + thermometer activations, high-precision residual stream
  (paper §III-B).  This is what ``train_step`` lowers.
* ``sc_int``  — the integer inference datapath that is bit-equivalent to
  the silicon: int8 activations (q domain) x int8 ternary weights with an
  int32 accumulate (== BSN popcount) and an SI threshold epilogue.  This is
  what ``serve_step --quant sc_int`` lowers and what the Pallas
  ``ternary_matmul`` kernel implements.

The equivalence (qat-rounded values == alpha-scaled int path == bit-exact
bitstream path) is asserted in tests/test_sc_layers.py.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import si as si_mod
from .quant import (init_alpha, lsq_fake_quant, ternary_weight_init_alpha,
                    ternary_weight_quant, thermometer_act_quant)

__all__ = [
    "SCQuantConfig",
    "SC_OFF",
    "init_sc_linear",
    "sc_linear_qat",
    "export_sc_linear",
    "sc_linear_int",
    "sc_linear_int_approx",
    "sc_linear_int_from_qat",
    "sc_residual_quant",
]


@dataclass(frozen=True)
class SCQuantConfig:
    """Per-model SC quantization settings (paper notation W-A-R/BSL)."""
    mode: str = "none"              # none | sc_qat | sc_int
    weight_bsl: int = 2             # ternary weights
    act_bsl: int = 8                # datapath activation BSL
    resid_bsl: int = 16             # high-precision residual BSL
    per_channel: bool = True        # per-output-channel weight scales
    # sc_int only: accumulate through the paper's approximate BSN adder
    # (kernels/dispatch) instead of the exact int32 dot
    int_approx: bool = False

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @property
    def act_half(self) -> int:
        return self.act_bsl // 2

    @property
    def resid_half(self) -> int:
        return self.resid_bsl // 2

    def with_mode(self, mode: str) -> "SCQuantConfig":
        return replace(self, mode=mode)


SC_OFF = SCQuantConfig(mode="none")


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_sc_linear(key: jax.Array, in_dim: int, out_dim: int,
                   cfg: SCQuantConfig,
                   w_init_scale: float | None = None,
                   dtype=jnp.float32) -> dict:
    """Linear params + LSQ scales. ``w`` stored (in_dim, out_dim)."""
    scale = w_init_scale if w_init_scale is not None else 1.0 / np.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * scale
    params = {"w": w}
    if cfg.enabled:
        if cfg.per_channel:
            aw = jnp.maximum(1.4 * jnp.mean(jnp.abs(w), axis=0), 1e-8)
        else:
            aw = ternary_weight_init_alpha(w)
        params["alpha_w"] = aw.astype(jnp.float32)
        # activation scale initialized for unit-variance inputs
        params["alpha_a"] = jnp.asarray(
            2.0 / np.sqrt(max(cfg.act_half, 1)), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# QAT path
# ---------------------------------------------------------------------------

def sc_linear_qat(params: dict, x: jax.Array, cfg: SCQuantConfig) -> jax.Array:
    """Fake-quant linear: quantize activations + weights, matmul in the
    compute dtype. With mode == none this is a plain matmul."""
    w = params["w"]
    if not cfg.enabled:
        return x @ w
    x_fq = thermometer_act_quant(x, params["alpha_a"], cfg.act_bsl)
    w_fq = ternary_weight_quant(w, params["alpha_w"])
    return x_fq.astype(x.dtype) @ w_fq.astype(x.dtype)


def sc_residual_quant(r: jax.Array, alpha_r: jax.Array,
                      cfg: SCQuantConfig) -> jax.Array:
    """High-precision residual fake-quant (16-bit BSL by default, §III)."""
    if not cfg.enabled:
        return r
    return lsq_fake_quant(r, alpha_r, -cfg.resid_half, cfg.resid_half)


# ---------------------------------------------------------------------------
# integer (silicon-equivalent) path
# ---------------------------------------------------------------------------

def export_sc_linear(params: dict, cfg: SCQuantConfig,
                     act_fn: Callable[[np.ndarray], np.ndarray] | None = None,
                     out_bsl: int | None = None,
                     alpha_out: float | None = None) -> dict:
    """Quantize trained params into the deployable integer form.

    Returns ``{"w_int": int8 (in,out), "alpha_w", "alpha_a",
    "thresholds": int32 (out_bsl,) or None, "alpha_out"}``.

    The SI thresholds realize ``act_fn`` on the *accumulated* integer sum:
    sum value = alpha_a*alpha_w * sum_q, so the threshold table is designed
    over the sum's level range with effective input scale alpha_a*alpha_w.
    Per-channel weight scales get per-channel threshold tables (stacked).
    """
    w = np.asarray(params["w"], np.float32)
    aw = np.asarray(params["alpha_w"], np.float32)
    aa = float(params["alpha_a"])
    w_int = np.clip(np.round(w / aw), -1, 1).astype(np.int8)
    out = {"w_int": w_int, "alpha_w": aw, "alpha_a": aa, "thresholds": None,
           "alpha_out": None}
    if act_fn is not None:
        if out_bsl is None or alpha_out is None:
            raise ValueError("SI epilogue needs out_bsl and alpha_out")
        in_dim = w.shape[0]
        half = cfg.act_half
        sum_max = in_dim * half          # |sum_q| <= in_dim * L/2
        aw_vec = np.atleast_1d(aw)
        tables = [si_mod.si_thresholds(act_fn, 2 * sum_max, out_bsl,
                                       alpha_in=float(a) * aa,
                                       alpha_out=alpha_out)
                  for a in aw_vec]
        out["thresholds"] = np.stack(tables)      # (C or 1, out_bsl)
        out["alpha_out"] = alpha_out
        out["sum_max"] = sum_max
    return out


def _si_epilogue(int_params: dict, sum_q: jax.Array) -> jax.Array:
    """Optional SI threshold activation on accumulated q-domain sums."""
    thresholds = int_params.get("thresholds")
    if thresholds is None:
        return sum_q
    t = jnp.asarray(thresholds)                    # (C or 1, out_bsl)
    sum_max = int(int_params["sum_max"])
    counts = sum_q + sum_max                       # count domain
    # counts (..., C) -> (..., C, 1) vs t (C, out_bsl): broadcast compare
    out_counts = jnp.sum(counts[..., None] >= t, axis=-1, dtype=jnp.int32)
    out_bsl = t.shape[-1]
    return out_counts - out_bsl // 2               # back to q domain


def sc_linear_int(int_params: dict, x_q: jax.Array,
                  matmul_fn: Callable | None = None) -> jax.Array:
    """Integer datapath: x_q int8 levels @ ternary int8 weights -> int32 sum
    (== the exact BSN's popcount, proven in tests), then optional SI
    epilogue.

    ``matmul_fn(x_q, w_int)`` may be supplied to route through the Pallas
    kernel; default is the jnp reference (int32 accumulate).  For the
    paper's proposed approximate adder use :func:`sc_linear_int_approx`.
    """
    w_int = jnp.asarray(int_params["w_int"])
    if matmul_fn is None:
        sum_q = jax.lax.dot_general(
            x_q.astype(jnp.int32), w_int.astype(jnp.int32),
            (((x_q.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        sum_q = matmul_fn(x_q, w_int)
    return _si_epilogue(int_params, sum_q)


def sc_linear_int_approx(int_params: dict, x_q: jax.Array,
                         act_bsl: int,
                         spec: "ApproxBSNSpec | None" = None,
                         *, cycles: int = 1,
                         backend: str | None = None) -> jax.Array:
    """Integer datapath with the *approximate* progressive-sorting adder.

    Replaces the exact accumulation of :func:`sc_linear_int` with the
    paper's Fig 10b/12 BSN, executed by the fused Pallas kernel through
    the dispatch layer (kernels/dispatch.py) — this is the silicon the
    efficiency results are about.  Per output channel the ``K`` partial
    products (levels in ``[-act_bsl/2, act_bsl/2]``, i.e. thermometer
    codes of BSL ``act_bsl``) enter the adder in the count domain; the
    compressed output code is re-scaled by ``spec.scale`` (a power of
    two, the §III-C residual re-scaler) back to the q domain, then the
    usual SI epilogue applies.

    ``spec`` defaults to :func:`default_approx_spec` of the accumulation
    width; with ``cycles > 1`` the temporal-reuse kernel folds
    ``cycles * spec.width == K`` inputs onto the small spatial pipeline.
    Exactness: with a degenerate spec (no clip, stride 1) the result
    equals :func:`sc_linear_int` bit-for-bit (asserted in tests).
    """
    from repro.core.bsn import approx_bsn, default_approx_spec
    w_int = jnp.asarray(int_params["w_int"])       # (K, N)
    k, _ = w_int.shape
    if spec is None:
        spec = default_approx_spec(k // cycles, act_bsl)
    if cycles * spec.width != k:
        raise ValueError(f"cycles*width={cycles * spec.width} != K={k}")
    if spec.in_bsl != act_bsl:
        raise ValueError(f"spec.in_bsl={spec.in_bsl} != act_bsl={act_bsl}")
    half = act_bsl // 2
    # partial products, one thermometer code per (input, channel) pair
    prod_q = x_q[..., :, None].astype(jnp.int32) * w_int.astype(jnp.int32)
    counts = jnp.swapaxes(prod_q, -1, -2) + half   # (..., N, K) in [0, bsl]
    out = approx_bsn(counts, spec, cycles=cycles, backend=backend)
    sum_q = spec.scale * (out - cycles * spec.out_bsl // 2)
    return _si_epilogue(int_params, sum_q)


def sc_linear_int_from_qat(params: dict, x: jax.Array,
                           cfg: SCQuantConfig, *,
                           backend: str | None = None) -> jax.Array:
    """Run a QAT-trained linear on the integer SC datapath, on the fly.

    This is what lets the *whole model zoo* serve on the silicon path
    without an export step: ``params`` are the live QAT params
    (``w/alpha_w/alpha_a``); activations and weights are quantized to
    their integer codes exactly as the fake-quant forward would round
    them, the accumulation runs int8 x ternary -> int32 (== the exact
    BSN popcount), and the result is rescaled back to the float residual
    stream.  With ``cfg.int_approx`` the accumulation instead goes
    through the paper's approximate progressive-sorting BSN
    (:func:`sc_linear_int_approx`), which dispatches to the fused Pallas
    kernel via kernels/dispatch — an ambient ``backend_scope`` (e.g. the
    one ServeEngine installs) picks pallas / interpret / reference.

    Numerics: with the exact accumulator the only difference from
    ``sc_linear_qat`` is summation order (int32 exact vs float dot), so
    q-domain values agree bit-for-bit and the float output to ~1 ulp.
    """
    half = cfg.act_half
    # mirror lsq_fake_quant's dtype discipline: the rounding boundary is
    # computed against alpha cast to the activation dtype
    aa = params["alpha_a"].astype(x.dtype)
    aw = params["alpha_w"].astype(jnp.float32)
    x_q = jnp.clip(jnp.round(x / aa), -half, half).astype(jnp.int8)
    w = params["w"].astype(jnp.float32)
    w_int = jnp.clip(jnp.round(w / aw), -1, 1).astype(jnp.int8)
    int_params = {"w_int": w_int}
    if cfg.int_approx:
        sum_q = sc_linear_int_approx(int_params, x_q, cfg.act_bsl,
                                     backend=backend)
    else:
        sum_q = sc_linear_int(int_params, x_q)
    y = sum_q.astype(jnp.float32) * (aa.astype(jnp.float32)
                                     * jnp.atleast_1d(aw))
    return y.astype(x.dtype)

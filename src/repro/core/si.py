"""Selective Interconnect — accumulation-fused activation (paper §II-B, Fig 3b, Fig 7).

After the BSN, the sorted vector ``s`` is deterministic: ``s[k] = 1  iff
count >= k+1``.  Wiring output bit ``j`` to sorted position ``t_j - 1``
therefore realizes

    out_count(c) = #{ j : c >= t_j },   t_1 <= t_2 <= ... <= t_Lout

i.e. *any* monotone non-decreasing step function with steps of height one —
exactly and with zero logic (routing only).  ReLU, saturating tanh, and the
BN-fused ReLU of Eq. 1 are all such functions once quantized.

Count-domain convention: input count ``c in [0, in_max]`` represents value
``alpha_in * (c - in_max/2)``; output count ``o in [0, out_bsl]`` represents
``alpha_out * (o - zero_point)`` with ``zero_point = out_bsl/2`` by default
(symmetric thermometer coding, so downstream negation stays a wiring op).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "si_thresholds_from_counts",
    "si_thresholds",
    "apply_si_counts",
    "apply_si_bits",
    "relu_fn",
    "bn_relu_fn",
    "tanh_fn",
    "gelu_mono_fn",
    "silu_mono_fn",
    "relu2_fn",
    "identity_fn",
]

# argmin locations of the non-monotone activations (see DESIGN.md §3):
# below these the SI uses the monotone clamp approximation.
_GELU_XSTAR = -0.75179
_SILU_XSTAR = -1.27846


# ---------------------------------------------------------------------------
# threshold design
# ---------------------------------------------------------------------------

def si_thresholds_from_counts(out_counts: np.ndarray, out_bsl: int) -> np.ndarray:
    """Thresholds from a tabulated monotone ``out_count(c)``, c = 0..in_max.

    Returns int32 ``(out_bsl,)`` with ``t_j in [0, in_max+1]``;
    ``t_j = in_max+1`` means output bit j is constant 0.
    """
    oc = np.asarray(out_counts, dtype=np.int64)
    if np.any(oc[1:] < oc[:-1]):
        raise ValueError("SI target function must be monotone non-decreasing")
    oc = np.clip(oc, 0, out_bsl)
    in_max = oc.shape[0] - 1
    # t_j = min{c : oc[c] >= j}  (searchsorted on the monotone table)
    js = np.arange(1, out_bsl + 1)
    t = np.searchsorted(oc, js, side="left")
    t = np.where(js > oc[-1], in_max + 1, t)
    return t.astype(np.int32)


def si_thresholds(fn: Callable[[np.ndarray], np.ndarray],
                  in_max: int,
                  out_bsl: int,
                  alpha_in: float = 1.0,
                  alpha_out: float = 1.0,
                  zero_point: float | None = None) -> np.ndarray:
    """Design thresholds for a float activation ``fn`` (vectorized, monotone).

    value_in  = alpha_in  * (c - in_max/2)
    value_out = alpha_out * (o - zero_point),   zero_point default out_bsl/2
    """
    if zero_point is None:
        zero_point = out_bsl / 2
    c = np.arange(in_max + 1, dtype=np.float64)
    v = alpha_in * (c - in_max / 2)
    y = np.asarray(fn(v), dtype=np.float64)
    oc = np.clip(np.round(y / alpha_out + zero_point), 0, out_bsl)
    # float rounding can produce 1-ulp non-monotonicity on flat regions
    oc = np.maximum.accumulate(oc)
    return si_thresholds_from_counts(oc.astype(np.int64), out_bsl)


# ---------------------------------------------------------------------------
# application (count-domain functional form and bit-exact form)
# ---------------------------------------------------------------------------

def apply_si_counts(c: jax.Array, thresholds: jax.Array) -> jax.Array:
    """out_count = #{j : c >= t_j}; thresholds sorted ascending.

    Vector form used by the reference path; the Pallas epilogue uses the
    identical comparison (see kernels/ternary_matmul.py).
    """
    t = thresholds.astype(jnp.int32)
    return jnp.sum(c[..., None].astype(jnp.int32) >= t, axis=-1,
                   dtype=jnp.int32)


def apply_si_bits(sorted_bits: jax.Array, thresholds: jax.Array) -> jax.Array:
    """Bit-exact SI: tap sorted wire ``t_j - 1`` (constants at the rails).

    ``sorted_bits``: (..., in_max) descending thermometer code.
    """
    in_max = sorted_bits.shape[-1]
    t = jnp.asarray(thresholds, dtype=jnp.int32)
    pos = jnp.clip(t - 1, 0, in_max - 1)
    tapped = sorted_bits[..., pos]
    always_one = (t <= 0)
    always_zero = (t >= in_max + 1)
    out = jnp.where(always_one, 1, jnp.where(always_zero, 0, tapped))
    return out.astype(jnp.int8)


# ---------------------------------------------------------------------------
# activation builders (float domain, handed to si_thresholds)
# ---------------------------------------------------------------------------

def identity_fn(x: np.ndarray) -> np.ndarray:
    return x


def relu_fn(x: np.ndarray) -> np.ndarray:
    return np.maximum(x, 0.0)


def relu2_fn(x: np.ndarray) -> np.ndarray:
    """Squared ReLU (nemotron FFN) — monotone, exactly SI-realizable."""
    return np.square(np.maximum(x, 0.0))


def bn_relu_fn(gamma: float, beta: float) -> Callable[[np.ndarray], np.ndarray]:
    """Paper Eq. 1: ReLU(BN(x)) = gamma*(x-beta) for x>=beta else 0.

    Requires gamma > 0 (gamma < 0 is folded into the preceding weights'
    sign at export time — see sc_layers.export).
    """
    if gamma <= 0:
        raise ValueError("bn_relu_fn requires gamma > 0; fold the sign "
                         "into the upstream weights first")

    def fn(x: np.ndarray) -> np.ndarray:
        return np.where(x >= beta, gamma * (x - beta), 0.0)

    return fn


def tanh_fn(scale: float = 1.0) -> Callable[[np.ndarray], np.ndarray]:
    def fn(x: np.ndarray) -> np.ndarray:
        return np.tanh(x / scale)

    return fn


def _gelu(x: np.ndarray) -> np.ndarray:
    return x * 0.5 * (1.0 + np.tanh(np.sqrt(2 / np.pi) * (x + 0.044715 * x ** 3)))


def gelu_mono_fn(x: np.ndarray) -> np.ndarray:
    """Monotone clamp of GELU: exact for x >= x* (= -0.7518), flat below.

    Max pointwise error = |gelu(x) - gelu(x*)| <= 0.17 for x < x*; the
    paper defers exact GELU to the ASCEND follow-up [12].
    """
    return _gelu(np.maximum(x, _GELU_XSTAR))


def silu_mono_fn(x: np.ndarray) -> np.ndarray:
    """Monotone clamp of SiLU/Swish (phi3/llava FFN gates)."""
    xc = np.maximum(x, _SILU_XSTAR)
    return xc / (1.0 + np.exp(-xc))

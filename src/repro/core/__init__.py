"""The paper's contribution: deterministic-coding end-to-end SC datapath.

Modules:
  coding       — thermometer en/decode (Table II)
  multiplier   — ternary SC multiplier (Fig 3a)
  bsn          — exact + approximate spatial-temporal sorting networks (§II, §IV)
  si           — selective-interconnect activation / BN fusion (Fig 3b, Eq 1)
  quant        — LSQ-style SC-friendly QAT (§III-B)
  residual     — high-precision residual re-scaling block (§III-C)
  fault        — bit-error injection (Fig 5)
  hwmodel      — gate-level area/delay/energy model (Tables IV/V, Figs 2/4/9/13)
  fsm_baseline — the stochastic FSM designs the paper improves on (Fig 1)
  sc_layers    — composable SC-quantized layers (QAT + integer paths)
"""

from . import (bsn, coding, fault, fsm_baseline, hwmodel, multiplier, quant,
               residual, sc_layers, si)
from .sc_layers import SC_OFF, SCQuantConfig

__all__ = [
    "bsn", "coding", "fault", "fsm_baseline", "hwmodel", "multiplier",
    "quant", "residual", "sc_layers", "si", "SCQuantConfig", "SC_OFF",
]

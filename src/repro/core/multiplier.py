"""Ternary SC multiplier (paper §II-B, Fig 3a).

The paper's deterministic multiplier takes a 2-bit thermometer activation
``a`` and a 2-bit thermometer weight ``w`` (both ternary, {-1,0,+1}) and
produces their 2-bit thermometer product with ~5 logic gates.

Truth table (q domain)::

        w\\a   -1   0   +1
        -1     +1   0   -1
         0      0   0    0
        +1     -1   0   +1

Bit-level derivation.  Write a ternary code as (f, s) = (first bit, second
bit): -1 = (0,0), 0 = (1,0), +1 = (1,1); thermometer implies f >= s.
For the product code (pf, ps):

    product == -1  iff  (a==+1 and w==-1) or (a==-1 and w==+1)
    =>  pf = (fa | ~sw) & (fw | ~sa)
    product == +1  iff  (a==+1 and w==+1) or (a==-1 and w==-1)
    =>  ps = (sa & sw) | (~fa & ~fw)

which is 6 two-input gates before sharing / 5 after the De-Morgan share of
the inverted pair — matching the paper's gate count (tracked in
:mod:`repro.core.hwmodel`).  The generalized form used by the wider
datapaths (ternary weight x L-bit activation) is pass / zero-code / negate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .coding import check_bsl, negate_bits, zero_code

__all__ = [
    "ternary_mul_bits",
    "ternary_mul_q",
    "ternary_scale_bits",
    "TERNARY_MUL_GATES",
]

# gate count of the 2-bit multiplier, used by the hardware cost model
TERNARY_MUL_GATES = 5


def ternary_mul_bits(a_bits: jax.Array, w_bits: jax.Array) -> jax.Array:
    """Gate-level 2-bit ternary multiplier. Inputs/outputs int8 ``(..., 2)``.

    Implements exactly the gate network documented in the module docstring;
    used to validate the functional q-domain path bit-for-bit.
    """
    if a_bits.shape[-1] != 2 or w_bits.shape[-1] != 2:
        raise ValueError("ternary_mul_bits operates on 2-bit BSL codes")
    fa, sa = a_bits[..., 0].astype(jnp.int32), a_bits[..., 1].astype(jnp.int32)
    fw, sw = w_bits[..., 0].astype(jnp.int32), w_bits[..., 1].astype(jnp.int32)
    # pf = (fa | ~sw) & (fw | ~sa)
    pf = jnp.clip(fa + (1 - sw), 0, 1) * jnp.clip(fw + (1 - sa), 0, 1)
    # ps = (sa & sw) | (~fa & ~fw)
    ps = jnp.clip(sa * sw + (1 - fa) * (1 - fw), 0, 1)
    return jnp.stack([pf, ps], axis=-1).astype(jnp.int8)


def ternary_mul_q(a_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """Functional (q domain) equivalent: plain integer product."""
    return a_q.astype(jnp.int32) * w_q.astype(jnp.int32)


def ternary_scale_bits(w_q: jax.Array, a_bits: jax.Array) -> jax.Array:
    """Generalized multiplier: ternary weight x L-bit thermometer activation.

    w=+1 passes the code, w=0 emits the zero code, w=-1 emits the negated
    code (complement+reverse) — all wiring-level operations in hardware.
    ``w_q`` broadcasts against ``a_bits[..., :-1]``.
    """
    bsl = a_bits.shape[-1]
    check_bsl(bsl)
    w = w_q[..., None].astype(jnp.int32)
    neg = negate_bits(a_bits)
    zero = zero_code(bsl)
    zero = jnp.broadcast_to(zero, a_bits.shape)
    out = jnp.where(w > 0, a_bits, jnp.where(w < 0, neg, zero))
    return out.astype(jnp.int8)

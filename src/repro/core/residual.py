"""High-precision residual fusion + re-scaling block (paper §III).

The SC-friendly model keeps the *datapath* at 2-bit BSL but carries the
residual stream at 16-bit BSL (levels -8..+8 — Fig 6).  Before the residual
joins the accumulation, its scale must match the convolution products'
scale; the paper's re-scaling block aligns them by powers of two:

* multiply by 2^N  — replicate the bitstream 2^N times into the buffer
  (count doubles per step, zero level is preserved because the implicit
  offset L/2 doubles too);
* divide by 2^N    — N cycles of "keep 1 of 2 bits", each cycle appending
  the zero code ('11110000') to keep the BSL constant; in the value domain
  one cycle is ``v -> floor((v + 1)/2)`` (round-half-up).

Both are wiring/buffer operations — no arithmetic logic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pow2_exponent",
    "rescale_q",
    "rescale_bits_div2",
    "residual_add_q",
]


def pow2_exponent(alpha_from: float, alpha_to: float) -> int:
    """N such that alpha_from * 2^N best matches alpha_to (round(log2))."""
    return int(np.round(np.log2(alpha_to / alpha_from)))


def rescale_q(v_q: jax.Array, n: int) -> jax.Array:
    """q-domain re-scaling block: value * 2^n (n may be negative).

    n >= 0: exact (bitstream replication).
    n <  0: |n| divide cycles, each ``v -> floor((v+1)/2)`` — the bit-level
    subsample with centered phase, so dividing then decoding matches the
    hardware bit-for-bit (see tests/test_residual.py).
    """
    v = v_q.astype(jnp.int32)
    if n >= 0:
        return v * (1 << n)
    for _ in range(-n):
        v = (v + 1) >> 1
    return v


def rescale_bits_div2(bits: jax.Array) -> jax.Array:
    """One bit-level divide cycle on an L-bit thermometer code.

    Keep 1 of every 2 bits (phase 1: tap positions 0,2,4.. of the code —
    bit j out = bit 2j in), then append the L/2-bit zero code so the BSL is
    constant (the paper's '11110000' padding for L=16).

    Note the output is a *concatenation* of two thermometer codes, not one
    canonical code — which is exactly what the hardware produces and all
    the BSN accumulator needs (its value is popcount - L/2 in any order).
    """
    L = bits.shape[-1]
    half = L // 2
    kept = bits[..., 0:L:2]                       # floor((c+1)/2) ones
    quarter = half // 2
    pad_shape = bits.shape[:-1] + (half,)
    pad = jnp.concatenate(
        [jnp.ones(bits.shape[:-1] + (quarter,), jnp.int8),
         jnp.zeros(bits.shape[:-1] + (half - quarter,), jnp.int8)], axis=-1)
    assert pad.shape == pad_shape
    return jnp.concatenate([kept, pad], axis=-1)


def residual_add_q(conv_q: jax.Array, resid_q: jax.Array, n: int) -> jax.Array:
    """Accumulate a re-scaled residual with the conv partial sum (q domain).

    ``n`` is the residual's re-scale exponent into the conv scale
    (``alpha_resid * 2^-n == alpha_conv`` i.e. resid levels are worth
    ``2^n`` conv levels ... resolved by ``pow2_exponent`` at export).
    """
    return conv_q.astype(jnp.int32) + rescale_q(resid_q, n)

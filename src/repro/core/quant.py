"""SC-friendly quantization-aware training (paper §III-B, Table III).

The paper's co-designed models use:

* **ternary weights** (2-bit BSL thermometer codes, levels {-1,0,+1}) with a
  trained scale ``alpha_w`` — Table III shows weight ternarization alone
  costs ~0.3% accuracy;
* **low-BSL activations** (levels ``[-L/2, L/2]``) with a trained scale
  ``alpha_a`` — the accuracy cliff lives here, fixed by the high-precision
  residual (§III, :mod:`repro.core.residual`).

Both quantizers are LSQ-style (learned step size, Esser et al. 2020):
straight-through estimator for the rounding, an analytically-derived
gradient for the scale, and the 1/sqrt(N*Qp) gradient scale that keeps the
scale's learning rate commensurate with the weights'.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "lsq_fake_quant",
    "ternary_weight_quant",
    "thermometer_act_quant",
    "init_alpha",
    "ternary_weight_init_alpha",
]


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_fake_quant(x: jax.Array, alpha: jax.Array, qn: int, qp: int) -> jax.Array:
    """Fake-quantize ``x`` to integer levels [qn, qp] with step ``alpha``.

    Returns the dequantized value ``alpha * clip(round(x/alpha), qn, qp)``.
    ``alpha`` broadcasts against ``x`` (per-tensor scalar or per-channel).

    dtype discipline: the value path runs in ``x.dtype`` (alpha is cast
    down) so a bf16 model stays bf16 end-to-end — an f32 alpha would
    promote every activation/weight and, transitively, every TP
    all-reduce to f32 (measured 2x wire + memory on the train cells,
    EXPERIMENTS.md §Perf). q is a small exact integer; ``q*alpha`` in
    bf16 adds <=0.4% value rounding. The alpha *gradient* still
    accumulates in f32.
    """
    a = alpha.astype(x.dtype) if alpha.dtype != x.dtype else alpha
    q = jnp.clip(jnp.round(x / a), qn, qp)
    return q * a


def _lsq_fwd(x, alpha, qn, qp):
    a = alpha.astype(x.dtype) if alpha.dtype != x.dtype else alpha
    xs = x / a
    q = jnp.clip(jnp.round(xs), qn, qp)
    # grad scale stored as a static python float: x.size can exceed int32
    gscale = 1.0 / float(x.size * max(qp, 1)) ** 0.5
    return q * a, (xs, q, alpha, gscale)


def _lsq_bwd(qn, qp, res, g):
    xs, q, alpha, grad_scale = res
    in_range = (xs >= qn) & (xs <= qp)
    gx = jnp.where(in_range, g, jnp.zeros((), g.dtype))
    # d(out)/d(alpha): round(x/a) - x/a inside the range, the rail outside
    dalpha = jnp.where(xs <= qn, float(qn),
                       jnp.where(xs >= qp, float(qp),
                                 (q - xs))).astype(jnp.float32)
    galpha_full = g.astype(jnp.float32) * dalpha * grad_scale
    # reduce over the broadcasted axes so galpha matches alpha's shape
    galpha = _reduce_to_shape(galpha_full, jnp.shape(alpha))
    return gx, galpha


def _reduce_to_shape(x: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    if shape == ():
        return jnp.sum(x)
    # sum leading broadcast axes
    while x.ndim > len(shape):
        x = jnp.sum(x, axis=0)
    axes = tuple(i for i, (a, b) in enumerate(zip(x.shape, shape)) if b == 1 and a != 1)
    if axes:
        x = jnp.sum(x, axis=axes, keepdims=True)
    return x.reshape(shape)


lsq_fake_quant.defvjp(_lsq_fwd, _lsq_bwd)


def ternary_weight_quant(w: jax.Array, alpha: jax.Array) -> jax.Array:
    """2-bit-BSL (ternary) weight fake-quant: levels {-1, 0, +1}."""
    return lsq_fake_quant(w, alpha, -1, 1)


def thermometer_act_quant(x: jax.Array, alpha: jax.Array, bsl: int) -> jax.Array:
    """L-bit-BSL activation fake-quant: levels [-L/2, L/2] (L+1 of them)."""
    half = bsl // 2
    return lsq_fake_quant(x, alpha, -half, half)


def init_alpha(x: jax.Array, qp: int) -> jax.Array:
    """LSQ init: 2 * mean|x| / sqrt(qp)."""
    return 2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(float(max(qp, 1)))


def ternary_weight_init_alpha(w: jax.Array) -> jax.Array:
    """TWN-flavored init for ternary weights: 0.7 * mean|w| is the classic
    threshold; LSQ's 2*mean|w| works as the *step*, use the midpoint."""
    return jnp.maximum(1.4 * jnp.mean(jnp.abs(w)), 1e-8)

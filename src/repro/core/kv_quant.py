"""Compressed storage formats for the paged KV cache (serving pools).

The serving engine's page pools (serving/paging.py layout, models/
transformer.init_paged_cache shapes) can hold K/V in three formats:

* ``"fp"``    — pages in the compute dtype (the original layout).
* ``"int8"``  — pages as int8 *levels* with an f32 scale per cached
  position per KV head (the scale pool rides a parallel
  ``(num_pages, page, Hkv)`` pool).  ``alpha = amax / 127`` over each
  head's ``Dh`` vector, value ``= alpha * level`` — the inference-time
  quantizer of :func:`repro.core.coding.quantize_levels` at BSL 254.
* ``"sc"``    — the paper's deterministic thermometer coding with the
  pow2-rescaled high-precision residual correction (paper §III,
  :mod:`repro.core.coding` / :mod:`repro.core.residual`): a coarse
  BSL-16 code (levels −8..+8 at ``alpha_c = amax / 8``) plus a BSL-16
  residual code at ``alpha_r = alpha_c * 2**-SC_SHIFT``; the dequantized
  value is ``alpha_r * residual_add_q(resid, code, SC_SHIFT)`` — the
  residual re-joins the coarse stream through the same pow2 re-scaling
  block the SC datapath uses, so the cache lives on the SC number
  system end to end.

Scales are PER POSITION PER HEAD (one f32 per cached ``Dh`` vector),
not per page: decode appends one token at a time, and a per-page scale
would force whole-page requantization whenever a new token's amax
exceeded the page's old scale.  Per-position scales make every write
independent — quantize-on-scatter never touches previously written
positions, which is what keeps batched and sequential serving
bit-identical within a format.

Error contracts (enforced by tests/test_kv_format.py):

* int8: ``|x - dequant| <= scale / 2``             (= amax / 254)
* sc:   ``|x - dequant| <= scale * 2**-SC_SHIFT / 2``  (= amax / 256)
* the residual scale ratio is exactly ``2**-SC_SHIFT``
  (``pow2_exponent(alpha_r, alpha_c) == SC_SHIFT``), and the residual
  never clips: ``|r| <= alpha_c / 2 = (BSL/2) * alpha_r`` exactly.
* zero round-trips exactly in every format (all-zero pools — the trash
  page, unwritten positions — dequantize to 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .coding import quantize_levels
from .residual import residual_add_q

__all__ = ["KV_FORMATS", "INT8_BSL", "SC_COARSE_BSL", "SC_RESID_BSL",
           "SC_SHIFT", "kv_quant", "kv_dequant", "kv_error_bound",
           "kv_format_of", "check_kv_format"]

KV_FORMATS = ("fp", "int8", "sc")

INT8_BSL = 254                # levels -127..+127 fill the int8 range
SC_COARSE_BSL = 16            # paper's high-precision BSL: levels -8..+8
SC_RESID_BSL = 16
SC_SHIFT = 4                  # alpha_resid = alpha_coarse * 2**-SC_SHIFT


def check_kv_format(fmt: str) -> str:
    if fmt not in KV_FORMATS:
        raise ValueError(f"kv_format must be one of {KV_FORMATS}, "
                         f"got {fmt!r}")
    return fmt


def kv_format_of(entry: dict) -> str:
    """Infer the storage format from a pool-dict's keys (the pools are
    self-describing: presence of the scale / residual leaves IS the
    format, so no config threading through the model stack)."""
    if "k_resid" in entry:
        return "sc"
    if "k_scale" in entry:
        return "int8"
    return "fp"


def _amax_scale(x: jax.Array, half: int) -> jax.Array:
    """Per-(…, head) scale over the trailing Dh axis: amax / half, floored
    away from zero so all-zero vectors quantize to exact zeros."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    return jnp.maximum(amax / half, jnp.finfo(jnp.float32).tiny)


def kv_quant(x: jax.Array, fmt: str) -> dict:
    """Quantize a K or V tensor ``(..., H, Dh)`` for pool storage.

    Returns ``{"q": int8 levels, "scale": f32 (..., H)}`` for int8,
    plus ``"resid"`` (int8 levels) for sc; ``{"q": x}`` unchanged for fp.
    """
    check_kv_format(fmt)
    if fmt == "fp":
        return {"q": x}
    if fmt == "int8":
        scale = _amax_scale(x, INT8_BSL // 2)
        q = quantize_levels(x.astype(jnp.float32), scale[..., None],
                            INT8_BSL)
        return {"q": q.astype(jnp.int8), "scale": scale}
    # sc: coarse thermometer code + pow2-rescaled residual
    scale = _amax_scale(x, SC_COARSE_BSL // 2)          # alpha_c
    xf = x.astype(jnp.float32)
    code = quantize_levels(xf, scale[..., None], SC_COARSE_BSL)
    alpha_r = scale * (2.0 ** -SC_SHIFT)
    r = xf - scale[..., None] * code.astype(jnp.float32)
    resid = quantize_levels(r, alpha_r[..., None], SC_RESID_BSL)
    return {"q": code.astype(jnp.int8), "scale": scale,
            "resid": resid.astype(jnp.int8)}


def kv_dequant(q: jax.Array, scale: jax.Array | None = None,
               resid: jax.Array | None = None, *, fmt: str,
               dtype=jnp.float32) -> jax.Array:
    """Pool storage -> float.  ``scale`` broadcasts over the trailing Dh
    axis (``scale.shape == q.shape[:-1]``)."""
    check_kv_format(fmt)
    if fmt == "fp":
        return q.astype(dtype)
    if fmt == "int8":
        return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
    # sc: the residual re-scaling block — resid levels join the coarse
    # code at 2**SC_SHIFT coarse-levels-per-resid-level, then one scale
    # (alpha_r) maps the fused sum back to value domain
    fused = residual_add_q(resid, q, SC_SHIFT)          # q*2^s + resid
    alpha_r = scale * (2.0 ** -SC_SHIFT)
    return (fused.astype(jnp.float32) * alpha_r[..., None]).astype(dtype)


def kv_error_bound(scale: jax.Array, fmt: str) -> jax.Array:
    """Elementwise absolute round-trip error bound per stored value."""
    check_kv_format(fmt)
    if fmt == "fp":
        return jnp.zeros_like(scale)
    if fmt == "int8":
        return scale * 0.5
    return scale * (2.0 ** -SC_SHIFT) * 0.5

"""Gate-level hardware cost model (paper Figs 2, 4, 9, 13; Tables IV, V).

This container has no 28-nm PDK, so area/delay/energy are *modeled* from
first principles (Batcher comparator counts) with two unit constants
calibrated so the model reproduces the paper's Table V baseline exactly:

    baseline BSN for a 3x3x512 conv (4608 products x 2-bit BSL = 9216 bits,
    padded to 16384): area 2.95e5 um^2, delay 4.33 ns.

    comparators(16384) = 16384*14*15/4 = 860,160; 2 gates each
      -> GATE_AREA_UM2  = 2.95e5 / 1.72e6  = 0.1715 um^2/gate   (28nm NAND2-ish)
    depth(16384) = 14*15/2 = 105 comparator levels
      -> LEVEL_DELAY_NS = 4.33 / 105       = 0.04124 ns/level   (~2 FO4)

Everything else (approximate BSNs, multipliers, SI) is *predicted* from the
same constants, and the benchmarks compare the predicted ratios against the
paper's reported ratios (2.8x / 4.1x ADP for Table V, 8.2-23.3x for Fig 13).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .bsn import ApproxBSNSpec
from .multiplier import TERNARY_MUL_GATES

__all__ = [
    "GATE_AREA_UM2",
    "LEVEL_DELAY_NS",
    "bitonic_comparators",
    "bitonic_depth",
    "BlockCost",
    "bsn_cost",
    "approx_bsn_cost",
    "spatial_temporal_cost",
    "multiplier_array_cost",
    "datapath_cost",
    "tops_per_watt",
]

GATE_AREA_UM2 = 2.95e5 / (2 * 860160)      # calibrated (see module docstring)
LEVEL_DELAY_NS = 4.33 / 105                # calibrated
GATES_PER_COMPARATOR = 2                   # AND + OR on 1-bit wires
# energy: calibrated so the §II silicon's peak (198.9 TOPS/W @ 0.65 V,
# 200 MHz, 2-bit BSL MAC) is reproduced by tops_per_watt() below.
_EQUIV_GATES_PER_MAC_2BIT = TERNARY_MUL_GATES + 2 * 2 * 2.625  # mul + BSN share/bit
_PEAK_TOPS_PER_WATT = 198.9
_NOMINAL_V = 0.65
GATE_ENERGY_FJ = 1e3 / (_PEAK_TOPS_PER_WATT * _EQUIV_GATES_PER_MAC_2BIT * 0.5)


def _ceil_pow2(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


def bitonic_comparators(n_bits: int) -> int:
    """Comparator count of a Batcher bitonic sorter over n wires (padded)."""
    m = _ceil_pow2(n_bits)
    lg = m.bit_length() - 1
    return m * lg * (lg + 1) // 4


def bitonic_depth(n_bits: int) -> int:
    """Comparator levels on the critical path."""
    m = _ceil_pow2(n_bits)
    lg = m.bit_length() - 1
    return lg * (lg + 1) // 2


@dataclass(frozen=True)
class BlockCost:
    area_um2: float
    delay_ns: float
    cycles: int = 1

    @property
    def adp(self) -> float:
        """Area-delay product, um^2 * ns (the paper's efficiency metric)."""
        return self.area_um2 * self.delay_ns * self.cycles

    def __add__(self, other: "BlockCost") -> "BlockCost":
        return BlockCost(self.area_um2 + other.area_um2,
                         self.delay_ns + other.delay_ns,
                         max(self.cycles, other.cycles))


def bsn_cost(n_bits: int) -> BlockCost:
    """Exact (baseline) BSN cost for an n-bit accumulation."""
    area = bitonic_comparators(n_bits) * GATES_PER_COMPARATOR * GATE_AREA_UM2
    delay = bitonic_depth(n_bits) * LEVEL_DELAY_NS
    return BlockCost(area, delay)


def approx_bsn_cost(spec: ApproxBSNSpec) -> BlockCost:
    """Spatial approximate BSN (paper §IV-B): sum of per-stage sub-BSNs.

    Sub-sampling/clipping is wiring (free); the cost is the sorters.  Stage
    i has m_i = width / prod(groups_<=i) sub-BSNs each sorting
    group_i * bsl_i wires.
    """
    area = 0.0
    delay = 0.0
    n_codes = spec.width
    bsls = spec.layer_bsls()
    for stage, bsl_in in zip(spec.stages, bsls[:-1]):
        n_codes //= stage.group
        sub = bsn_cost(stage.group * bsl_in)
        area += n_codes * sub.area_um2
        delay += sub.delay_ns
    return BlockCost(area, delay)


def spatial_temporal_cost(spec: ApproxBSNSpec, cycles: int) -> BlockCost:
    """Temporal folding: one spatial pipeline reused over ``cycles`` cycles,
    plus the small exact accumulator for the compressed partial sums."""
    spatial = approx_bsn_cost(spec)
    acc = bsn_cost(spec.out_bsl * cycles)
    area = spatial.area_um2 + acc.area_um2
    delay = spatial.delay_ns + acc.delay_ns / cycles   # pipelined accumulate
    return BlockCost(area, delay, cycles=cycles)


def multiplier_array_cost(width: int) -> BlockCost:
    """Ternary multiplier bank feeding the BSN (5 gates each, 1 level)."""
    return BlockCost(width * TERNARY_MUL_GATES * GATE_AREA_UM2,
                     2 * LEVEL_DELAY_NS)


def datapath_cost(width: int, adder: BlockCost) -> BlockCost:
    """One output neuron's datapath: multipliers + nonlinear adder (+SI)."""
    return multiplier_array_cost(width) + adder


def tops_per_watt(act_bsl: int = 2, voltage: float = _NOMINAL_V) -> float:
    """Peak efficiency model: 2 OPs per MAC; energy ~ gates * E_gate * V^2.

    Calibrated to the silicon's 198.9 TOPS/W at 0.65 V (Fig 4); the BSL
    scaling reflects that multiplier/adder gates grow ~linearly with BSL
    (the Fig 2 efficiency-vs-precision trade-off).
    """
    gates = _EQUIV_GATES_PER_MAC_2BIT * (act_bsl / 2)
    e_mac_fj = gates * GATE_ENERGY_FJ * (voltage / _NOMINAL_V) ** 2
    # TOPS/W = OPs/J: 2 ops per MAC, e_mac in fJ -> 2/e_mac * 1e3 TOPS/W
    return 2.0 / e_mac_fj * 1e3


def describe_spec(spec: ApproxBSNSpec, cycles: int = 1) -> str:
    stages = ", ".join(
        f"g{si.group}/c{si.sub.clip}/s{si.sub.stride}" for si in spec.stages)
    return (f"width={spec.width} bsl={spec.in_bsl} stages=[{stages}] "
            f"out_bsl={spec.out_bsl} scale={spec.scale} cycles={cycles}")

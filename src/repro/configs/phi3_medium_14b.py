"""phi3-medium-14b [dense]: RoPE SwiGLU GQA kv=10.

[arXiv:2404.14219; unverified] — 40L d=5120 40H (kv=10) d_ff=17920
vocab=100352. 40 heads over TP=16 exercises GSPMD uneven sharding.
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab_size=100352,
    period=(LayerSpec("attn", "dense"),),
    norm="rmsnorm", ffn_act="silu", ffn_gated=True,
    quant=DEFAULT_SC,
))

"""llava-next-34b [vlm]: Yi-34B-class LM backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf family; unverified] — 60L d=7168
56H (GQA kv=8) d_ff=20480 vocab=64000. The modality frontend is a STUB:
input_specs() provides precomputed patch embeddings (B, S_img, 1024)
(anyres tiling: 4 tiles + base = 5 x 576 = 2880 image tokens at train).
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    period=(LayerSpec("attn", "dense"),),
    norm="rmsnorm", ffn_act="silu", ffn_gated=True,
    rope_theta=5_000_000.0,
    frontend="vision_stub",
    quant=DEFAULT_SC,
))

IMG_TOKENS = 2880   # 5 anyres tiles x 576

"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, QK-norm, GQA kv=4.

[hf:Qwen/Qwen3-30B-A3B scaled per assignment; hf] — 94L d=4096 64H (kv=4)
expert d_ff=1536 vocab=151936. Expert weights are ~87% of active params —
the richest SC-quantization target in the pool.
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936,
    period=(LayerSpec("attn", "moe"),),
    norm="rmsnorm", ffn_act="silu", ffn_gated=True, qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128, n_experts_per_tok=8,
    quant=DEFAULT_SC,
))

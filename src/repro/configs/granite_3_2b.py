"""granite-3.0-2b [dense]: GQA kv=8, SwiGLU, RMSNorm.

[hf:ibm-granite/granite-3.0-2b-base; hf] — 40L d=2048 32H (kv=8)
d_ff=8192 vocab=49155 (padded to 49408 for TP — DESIGN.md §5).
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=49155,
    period=(LayerSpec("attn", "dense"),),
    norm="rmsnorm", ffn_act="silu", ffn_gated=True,
    quant=DEFAULT_SC,
))

"""nemotron-4-15b [dense]: squared-ReLU FFN, partial rotary, GQA kv=8.

[arXiv:2402.16819; unverified] — 32L d=6144 48H (kv=8) d_ff=24576
vocab=256000.  Squared-ReLU is monotone => the paper's BSN+SI realizes
this FFN activation EXACTLY — the showcase arch for the technique
(DESIGN.md §4), and the §Perf hillclimb cell for the sc_int datapath.
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab_size=256000,
    period=(LayerSpec("attn", "dense"),),
    norm="layernorm", ffn_act="relu2", ffn_gated=False,
    rope_fraction=0.5,
    quant=DEFAULT_SC,
))

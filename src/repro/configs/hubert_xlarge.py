"""hubert-xlarge [audio]: encoder-only, bidirectional, conv-stem stub.

[arXiv:2106.07447; unverified] — 48L d=1280 16H d_ff=5120 vocab=504
(masked-cluster prediction). Encoder-only => NO decode step: decode_32k
and long_500k cells are skipped (DESIGN.md §4). The 7-layer conv stem is
the STUB frontend: input_specs() provides (B, T, 512) frame features;
positions come from the (stubbed) conv positional encoding, so
rope_fraction=0.
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    period=(LayerSpec("attn", "dense"),),
    norm="layernorm", ffn_act="gelu", ffn_gated=False,
    causal=False, rope_fraction=0.0,
    frontend="audio_stub",
    quant=DEFAULT_SC,
))

"""dbrx-132b [moe]: 16 experts top-4, fine-grained.

[hf:databricks/dbrx-base; unverified] — 40L d=6144 48H (kv=8)
expert d_ff=10752 vocab=100352.
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    period=(LayerSpec("attn", "moe"),),
    norm="layernorm", ffn_act="silu", ffn_gated=True,
    rope_theta=500_000.0,
    n_experts=16, n_experts_per_tok=4,
    quant=DEFAULT_SC,
))

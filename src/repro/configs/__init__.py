"""Architecture registry: importing this package registers all configs."""

from . import (dbrx_132b, granite_3_2b, hubert_xlarge, jamba_1_5_large,
               llava_next_34b, nemotron_4_15b, paper_tnn, phi3_medium_14b,
               qwen3_moe_235b, rwkv6_7b, stablelm_1_6b)
from .base import (SHAPES, LayerSpec, ModelConfig, ShapeConfig, get_arch,
                   list_archs, register_arch, shape_by_name)

__all__ = ["SHAPES", "LayerSpec", "ModelConfig", "ShapeConfig", "get_arch",
           "list_archs", "register_arch", "shape_by_name"]

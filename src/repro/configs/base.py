"""Model / shape / run configuration system.

``ModelConfig`` describes an architecture as a *period* of layers (a layer
pattern repeated ``n_periods`` times) so heterogeneous stacks (Jamba's
1-attention:7-mamba interleave with alternating MoE) stack-scan exactly
like homogeneous ones.  ``ShapeConfig`` is one (seq_len, global_batch,
kind) cell of the assignment; ``RunConfig`` bundles everything a launcher
needs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

from repro.core.sc_layers import SC_OFF, SCQuantConfig

__all__ = [
    "LayerSpec",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "shape_by_name",
    "register_arch",
    "get_arch",
    "list_archs",
]


@dataclass(frozen=True)
class LayerSpec:
    """One layer within the repeating period."""
    mixer: str = "attn"        # attn | mamba | rwkv6 | none
    ffn: str = "dense"         # dense | moe | rwkv_cmix | none


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 -> d_model // n_heads
    period: tuple[LayerSpec, ...] = (LayerSpec(),)
    # normalization / activations
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    ffn_act: str = "silu"       # silu | gelu | relu2 | relu
    ffn_gated: bool = True
    # positional
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # stablelm-2 uses 0.25
    causal: bool = True         # encoders: False
    qk_norm: bool = False       # qwen3 per-head q/k RMSNorm
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba)
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0      # 0 -> ceil(d_model / 16)
    # RWKV
    rwkv_head_dim: int = 64
    rwkv_lora_w: int = 0        # 0 -> d_model // 32 (decay lora rank)
    rwkv_wkv_impl: str = "scan" # scan (token recurrence) | chunked (GLA
                                # quasi-matmul form — §Perf cell B)
    rwkv_chunk: int = 32
    # frontend stub (vlm / audio): inputs arrive as embeddings
    frontend: str = "none"      # none | vision_stub | audio_stub
    # output
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # quantization (the paper's technique)
    quant: SCQuantConfig = SC_OFF
    # numerics / memory
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # jamba-398b uses bfloat16 to fit HBM
    remat: str = "full"         # full | none  (per-layer remat policy)
    attn_q_chunk: int = 1024    # flash-attention scan block sizes
    attn_kv_chunk: int = 1024
    ce_chunks: int = 0          # >0: chunked cross-entropy (never
                                # materializes (B,S,V) logits — §Perf)
    mamba_chunk: int = 64
    moe_group_size: int = 1024  # tokens per dispatch group (GShard-style)
    # vocab padding for TP (actual table size rounded up)
    vocab_pad_multiple: int = 256

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, \
            f"{self.name}: n_layers {self.n_layers} % period {len(self.period)}"
        return self.n_layers // len(self.period)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def has_mixer(self, kind: str) -> bool:
        return any(l.mixer == kind for l in self.period)

    def has_ffn(self, kind: str) -> bool:
        return any(l.ffn == kind for l in self.period)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k contexts (no full-attn KV blowup
        OR hybrid where attention is sparse enough to shard)."""
        return self.has_mixer("mamba") or self.has_mixer("rwkv6")

    def with_quant(self, mode: str, **kw) -> "ModelConfig":
        return replace(self, quant=dataclasses.replace(
            self.quant if self.quant.enabled else SCQuantConfig(),
            mode=mode, **kw))

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_by_name(name: str) -> ShapeConfig:
    return SHAPES[name]


_ARCH_REGISTRY: dict[str, "ModelConfig"] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    _ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if name not in _ARCH_REGISTRY:
        # import the configs package to populate the registry lazily
        import repro.configs  # noqa: F401
    return _ARCH_REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_ARCH_REGISTRY)

"""jamba-1.5-large-398b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] — 72L d=8192 64H (kv=8) d_ff=24576 vocab=65536.
Period of 8 (attn at index 4, MoE on odd indices) x 9 periods. Runs
long_500k: the 9 attention layers' KV shards over the "seq" axis; Mamba
layers carry O(1) state. opt_state_dtype=bfloat16 to fit 16 GB/chip HBM on
the single-pod mesh (DESIGN.md §5).
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

_M, _A = "mamba", "attn"
_D, _E = "dense", "moe"
PERIOD = tuple(
    LayerSpec(_A if i == 4 else _M, _E if i % 2 == 1 else _D)
    for i in range(8))

CONFIG = register_arch(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    period=PERIOD,
    norm="rmsnorm", ffn_act="silu", ffn_gated=True,
    n_experts=16, n_experts_per_tok=2,
    mamba_expand=2, mamba_d_state=16, mamba_d_conv=4,
    opt_state_dtype="bfloat16",
    quant=DEFAULT_SC,
))

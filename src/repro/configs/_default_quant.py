"""Default SC quantization for the assigned archs: the paper's co-design
(ternary weights, thermometer activations, 16-bit-BSL residual) applied as
W2-A8-R16 — act BSL 8 rather than the paper's CIFAR-scale 2, per §III's own
accuracy-vs-BSL trade-off analysis at SOTA-model scale (DESIGN.md §3).
"""

from repro.core.sc_layers import SCQuantConfig

DEFAULT_SC = SCQuantConfig(mode="sc_qat", weight_bsl=2, act_bsl=8,
                           resid_bsl=16, per_channel=True)

"""The paper's own silicon model (§II-C): a ternary MLP for MNIST-class
10-way classification (the 28-nm chip's workload, 98.28% soft accuracy).

Used by the fault-tolerance benchmark (Fig 5) and the end-to-end QAT
example; not part of the LM zoo. Layer sizes follow the DATE'20/SSCL'22
TNN processor (784-256-256-10, all ternary, BSN+SI activations).
"""

TNN_LAYERS = (784, 256, 256, 10)
TNN_ACT_BSL = 2          # the chip's fully-ternary datapath
TNN_RESID_BSL = 16       # §III residual extension used by bench_residual

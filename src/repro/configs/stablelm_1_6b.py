"""stablelm-2-1.6b [dense]: LayerNorm + 25% partial rotary, MHA (kv=32).

[hf:stabilityai/stablelm-2-1_6b; unverified] — 24L d=2048 32H d_ff=5632
vocab=100352.
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab_size=100352,
    period=(LayerSpec("attn", "dense"),),
    norm="layernorm", ffn_act="silu", ffn_gated=True,
    rope_fraction=0.25,
    quant=DEFAULT_SC,
))

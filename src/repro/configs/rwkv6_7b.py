"""rwkv6-7b "Finch" [ssm]: attention-free, data-dependent decay.

[arXiv:2404.05892; hf] — 32L d=4096 d_ff=14336 vocab=65536. Runs
long_500k (O(1) recurrent state). SC quant covers the 6 projections per
layer; the wkv recurrence stays f32 (DESIGN.md §4).
"""

from .base import LayerSpec, ModelConfig, register_arch
from ._default_quant import DEFAULT_SC

CONFIG = register_arch(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,  # 64 wkv heads
    d_ff=14336, vocab_size=65536,
    period=(LayerSpec("rwkv6", "rwkv_cmix"),),
    norm="layernorm", rwkv_head_dim=64,
    quant=DEFAULT_SC,
))

"""Seeded stochastic sampling for the paged serving stack.

Per-request :class:`SamplingParams` (temperature / top-k / top-p / min-p /
seed) ride through admission and are packed into flat per-lane tensors,
so the WHOLE batch samples inside the one jitted decode step — masking,
renormalization and the categorical draw are traced jax, no host
round-trip, and the jit signature depends only on the pow2 shape
buckets plus one static bit (sampled vs all-greedy: batches without a
``temperature > 0`` lane compile :func:`greedy_tokens`, the plain
argmax step, so default serving pays no sampler compute).

The reproducibility contract
----------------------------

The per-request PRNG stream is a pure function of ``(seed, position)``::

    key(seed, t) = fold_in(PRNGKey(seed), t)      # t = token index drawn

where ``t`` is the 0-based index of the token being drawn in the full
sequence (prompt tokens occupy ``0..P-1``, so the first sampled token is
drawn at ``t = P``).  Nothing else enters the key — not the batch slot,
not the slot/page bucket size, not the mesh layout, not wall clock.
Consequences, all load-bearing for the engine:

* **batched == sequential** — the continuous-batching engine and the
  per-request ``sequential_generate`` oracle draw identical tokens;
* **preemption-safe** — a preempted request is re-prefilled and replays
  positions ``P, P+1, ...`` with the same keys, regenerating the exact
  tokens it lost (the same argument that made greedy preemption safe);
* **mesh-invariant** — the sampled-token tensor is pinned replicated
  (``constrain``), so tensor-parallel decode draws the same tokens as
  single-device decode.

Greedy decode is the ``temperature == 0`` special case: the sampler
returns the exact ``argmax`` the pre-sampling engine computed, so default
requests are bit-compatible with the old greedy-only engine.

Filtering order (applied to ``logits / temperature``):

1. **top-k**  — keep the k largest logits; ties *at* the k-th value are
   all kept (a pure function of the logit row, so slot/bucket invariant).
2. **top-p**  — over the top-k-renormalized probabilities, sort
   descending and keep the shortest prefix whose *preceding* mass is
   ``< top_p``; probability ties at the boundary are all kept (same
   invariance argument — the kept set never depends on sort tie order).
3. **min-p**  — keep tokens with ``prob >= min_p * max_prob``.
4. categorical draw via the Gumbel trick on the surviving logits.

The best token always survives every filter, so the masked row is never
empty.

Speculative coupling
--------------------

Because the draw is a Gumbel-argmax over the masked logits with noise
that depends ONLY on ``(seed, position)``, two different logit rows for
the same (request, position) — e.g. a draft datapath and a target
datapath — share their noise.  :func:`speculative_accept` exploits
this: the engine drafts ``d_t = argmax(mask(draft_logits) + g_t)`` and
verifies ``tau_t = argmax(mask(target_logits) + g_t)`` with the SAME
``g_t``, then accepts the longest prefix where they agree and always
emits the TARGET tokens.  Each ``tau_t`` is by construction an exact
draw from the target distribution (the Gumbel-max trick), so the
emitted stream is bit-identical to non-speculative decode — a stronger
property than the usual accept/resample rule's distribution equality.

Logprobs
--------

:func:`token_logprobs` returns per-token log-probabilities under the
distribution the token was ACTUALLY drawn from: greedy lanes score
against ``log_softmax`` of the raw (cropped, f32) logits; sampled lanes
score against ``log_softmax`` of the temperature-scaled, filtered
logits (masked-out tokens have logprob ``-inf``).  Computed inside the
jitted step — no host round-trip — and only when a request asked
(``SamplingParams.logprobs > 0`` anywhere in the batch), so the default
step compiles zero sampler/sort compute, exactly as before.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

__all__ = ["SamplingParams", "pack_sampling", "filter_logits",
           "sample_tokens", "greedy_tokens", "lane_keys",
           "token_logprobs", "speculative_accept"]


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls (vLLM-style semantics).

    ``temperature == 0`` is greedy argmax decode — the default, and the
    engine's historical behavior.  ``top_k == 0`` disables the top-k
    filter; ``top_p == 1`` and ``min_p == 0`` disable theirs.  ``seed``
    names the request's deterministic draw stream (two requests with the
    same seed and the same context draw the same tokens — reproducibility
    is the feature, perturb the seed for variety).  Only the low 32 bits
    of ``seed`` enter the PRNG key: seeds congruent mod 2**32 name the
    SAME stream (hash-derived seeds should be masked by the caller).

    ``logprobs = N`` asks the engine to return, for every generated
    token, the chosen token's log-probability plus the top-N
    (token, logprob) pairs — scored under the distribution the token was
    drawn from (see :func:`token_logprobs`).  ``0`` (the default)
    disables logprobs and compiles the historical step unchanged.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    min_p: float = 0.0
    seed: int = 0
    logprobs: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.logprobs < 0:
            raise ValueError(f"logprobs must be >= 0 (0 = off), "
                             f"got {self.logprobs}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), "
                             f"got {self.top_k}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if not 0 <= self.min_p <= 1:
            raise ValueError(f"min_p must be in [0, 1], got {self.min_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def pack_sampling(sps: list[SamplingParams], pad_to: int | None = None
                  ) -> dict[str, jax.Array]:
    """Pack per-request params into flat per-lane device tensors.

    Padded lanes get ``temperature = 0`` (greedy over garbage logits —
    their draw is discarded by the engine, and the greedy branch burns no
    RNG).  The dict is a single jit argument; shapes follow the lane
    bucket, so sampling never adds retraces.
    """
    n = len(sps) if pad_to is None else pad_to
    assert n >= len(sps), (n, len(sps))
    out = {"seed": np.zeros((n,), np.int32),
           "temperature": np.zeros((n,), np.float32),
           "top_k": np.zeros((n,), np.int32),
           "top_p": np.ones((n,), np.float32),
           "min_p": np.zeros((n,), np.float32)}
    for i, sp in enumerate(sps):
        out["seed"][i] = np.uint32(sp.seed & 0xFFFFFFFF).astype(np.int32)
        out["temperature"][i] = sp.temperature
        out["top_k"][i] = sp.top_k
        out["top_p"][i] = sp.top_p
        out["min_p"][i] = sp.min_p
    return {k: jnp.asarray(v) for k, v in out.items()}


def lane_keys(seeds: jax.Array, positions: jax.Array) -> jax.Array:
    """The (seed, position) fold-in stream — one key per lane.

    vmap over per-lane keys applies the counter-based PRNG per key, so a
    lane's bits are identical whether it is drawn alone (the sequential
    oracle), in an 8-wide bucket, or on a mesh.
    """
    def one(seed, pos):
        return jax.random.fold_in(jax.random.PRNGKey(seed), pos)
    return jax.vmap(one)(seeds, positions)


def filter_logits(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array,
                  min_p: jax.Array) -> jax.Array:
    """Temperature-scale then mask a batch of logit rows.

    logits: ``(S, V)`` float32 (already cropped to the real vocab);
    the per-lane controls are ``(S,)``.  Returns ``(S, V)`` scaled logits
    with ``-inf`` outside the kept set.  Every mask is a pure function of
    its own row, so the result is invariant to batch composition.
    """
    S, V = logits.shape
    # the greedy lanes divide by 1 (their branch ignores this tensor)
    scaled = logits / jnp.maximum(temperature, 1e-8)[:, None]

    # top-k: threshold at the k-th largest value, keep boundary ties
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V)
    kth = jnp.take_along_axis(sorted_desc, (k_eff - 1)[:, None], axis=-1)
    keep = scaled >= kth

    # top-p on the top-k-renormalized distribution: keep the shortest
    # descending prefix whose PRECEDING mass is < top_p, then widen to
    # every token tied with the smallest kept probability (boundary ties
    # must not depend on sort order between equal probs)
    probs = jax.nn.softmax(jnp.where(keep, scaled, -jnp.inf), axis=-1)
    sp = jnp.sort(probs, axis=-1)[:, ::-1]
    mass_before = jnp.cumsum(sp, axis=-1) - sp
    kept_sorted = mass_before < top_p[:, None]          # monotone prefix
    n_keep = jnp.sum(kept_sorted, axis=-1)              # >= 1 (top_p > 0)
    p_thr = jnp.take_along_axis(sp, (n_keep - 1)[:, None], axis=-1)
    keep = keep & (probs >= p_thr)

    # min-p relative to the row's best token
    pmax = jnp.max(probs, axis=-1, keepdims=True)
    keep = keep & (probs >= min_p[:, None] * pmax)

    return jnp.where(keep, scaled, -jnp.inf)


def greedy_tokens(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Argmax decode with the same vocab crop and sharding pins as
    :func:`sample_tokens` — the step traced for all-greedy batches, so
    the default serving path pays zero sampler compute (no sorts, no
    RNG).  Bit-identical to a ``temperature == 0`` lane of the sampled
    step (same f32 cast, same argmax), so a request draws the same
    tokens whether its batch happens to contain sampled neighbors."""
    lf = logits[:, :vocab_size].astype(jnp.float32)
    lf = constrain(lf, None, None)
    return constrain(jnp.argmax(lf, axis=-1).astype(jnp.int32), None)


def sample_tokens(logits: jax.Array, positions: jax.Array,
                  samp: dict[str, jax.Array], vocab_size: int) -> jax.Array:
    """Draw one token per lane, inside the caller's jit.

    logits: ``(S, V_padded)``; positions: ``(S,)`` int32 — the 0-based
    sequence index of the token being drawn (the fold-in counter);
    ``samp``: :func:`pack_sampling` output.  Returns ``(S,)`` int32.

    Lanes with ``temperature == 0`` return the exact argmax (the padded
    vocab is cropped first, so the ``-1e9`` vocab-bias slots can never
    win).  Under a mesh the logit rows are pinned replicated before the
    row-wise sort/scan ops and the sampled tokens are pinned replicated
    on the way out — tensor-parallel decode must draw the very token the
    single-device engine draws.
    """
    lf = logits[:, :vocab_size].astype(jnp.float32)
    lf = constrain(lf, None, None)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    masked = filter_logits(lf, samp["temperature"], samp["top_k"],
                           samp["top_p"], samp["min_p"])
    keys = lane_keys(samp["seed"], positions)
    u = jax.vmap(
        lambda k: jax.random.uniform(k, (vocab_size,), jnp.float32))(keys)
    gumbel = -jnp.log(-jnp.log(jnp.maximum(u, jnp.finfo(jnp.float32).tiny)))
    drawn = jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)

    nxt = jnp.where(samp["temperature"] > 0, drawn, greedy)
    return constrain(nxt, None)


def token_logprobs(logits: jax.Array, tokens: jax.Array,
                   samp: dict[str, jax.Array], vocab_size: int,
                   k: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Score drawn tokens under the distribution they were drawn from.

    logits: ``(S, V_padded)`` step logits; tokens: ``(S,)`` int32 the
    chosen tokens; ``k``: static top-k width (the batch max of
    ``SamplingParams.logprobs``).  Returns
    ``(chosen_lp (S,), top_ids (S, k), top_lp (S, k))`` float32/int32.

    Greedy lanes (``temperature == 0``) are scored against
    ``log_softmax`` of the raw cropped f32 logits — the model's actual
    next-token distribution.  Sampled lanes are scored against
    ``log_softmax`` of the :func:`filter_logits` output, i.e. the
    post-temperature post-filter distribution the categorical draw used;
    filtered-out tokens score ``-inf``.  Each row is a proper
    distribution (``logsumexp == 0``), which the tests pin.

    The ``jnp.where`` on rows (not a division by temperature) keeps
    greedy lanes free of the ``temperature -> 0`` blowup, and everything
    is pinned replicated so mesh runs return bit-identical logprobs.
    """
    lf = logits[:, :vocab_size].astype(jnp.float32)
    lf = constrain(lf, None, None)
    raw_lp = jax.nn.log_softmax(lf, axis=-1)
    masked = filter_logits(lf, samp["temperature"], samp["top_k"],
                           samp["top_p"], samp["min_p"])
    masked_lp = jax.nn.log_softmax(masked, axis=-1)
    lp = jnp.where((samp["temperature"] > 0)[:, None], masked_lp, raw_lp)
    chosen = jnp.take_along_axis(
        lp, tokens.astype(jnp.int32)[:, None], axis=-1)[:, 0]
    top_lp, top_ids = jax.lax.top_k(lp, max(k, 1))
    top_lp, top_ids = top_lp[:, :k], top_ids[:, :k].astype(jnp.int32)
    return (constrain(chosen, None),
            constrain(top_ids, None, None),
            constrain(top_lp, None, None))


def speculative_accept(draft: jax.Array, target: jax.Array) -> jax.Array:
    """Length of the accepted prefix, per lane.

    draft, target: ``(S, k)`` int32 token ids at the same positions,
    drawn with SHARED (seed, position) Gumbel noise (or both greedy).
    Returns ``(S,)`` int32 ``m`` = number of leading positions where
    they agree.  The engine then emits the k+1 target tokens' prefix
    ``tau_0 .. tau_m`` (the first m accepted drafts ARE the target
    draws, plus the bonus token verified at the first divergence).

    ``draft == target`` everywhere gives ``m == k`` — every token
    accepted — which the property tests pin; and because emitted tokens
    are always TARGET draws, distribution preservation is exact, not
    just in expectation.
    """
    match = (draft == target).astype(jnp.int32)
    return jnp.sum(jnp.cumprod(match, axis=-1), axis=-1)

"""Paged KV-cache bookkeeping: page pool allocator + per-request tables.

The device side of the paged cache is a flat pool of fixed-size pages per
attention layer (``(num_pages, page_size, Hkv, Dh)``); which physical
page holds which request's tokens is decided *here*, on the host, by a
free-list allocator.  A request's page table is a list of physical page
ids; position ``t`` of the request lives at
``(table[t // page_size], t % page_size)``.

Two conventions the device code relies on:

* **Page 0 is the trash page.**  The allocator never hands it out.
  Padded page-table lanes (inactive decode lanes, short prompts in a
  padded prefill bucket) point at page 0, so out-of-range *writes* land
  in the trash page and out-of-range *reads* are masked by the per-slot
  length — no cross-request corruption either way.
* Tables handed to the device are padded to a power-of-two page count
  (:func:`PageTable.padded`) so the jitted decode step retraces only on
  bucket changes, not on every length change.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["TRASH_PAGE", "PageAllocator", "PageTable", "pages_needed",
           "pad_pow2", "kv_page_bytes", "slots_per_gib"]

TRASH_PAGE = 0


def pages_needed(length: int, page_size: int) -> int:
    """Pages required to hold ``length`` tokens (ceil division)."""
    return max(0, (length + page_size - 1) // page_size)


def kv_page_bytes(page_size: int, n_kv_heads: int, head_dim: int,
                  kv_format: str = "fp", dtype_bytes: int = 4) -> int:
    """Device bytes one physical page costs per attention layer (K and V
    together), including the parallel scale / residual pools a
    compressed format carries alongside the code pages.

    * ``"fp"``   — two float pools: ``2 * page * Hkv * Dh * dtype_bytes``.
    * ``"int8"`` — int8 code pages plus one f32 scale per (position,
      head): ``2 * (page*Hkv*Dh + page*Hkv*4)``.
    * ``"sc"``   — int8 coarse codes + int8 residual pages + f32 scales:
      ``2 * (2*page*Hkv*Dh + page*Hkv*4)``.
    """
    elems = page_size * n_kv_heads * head_dim
    scales = page_size * n_kv_heads * 4            # f32 per-position-per-head
    if kv_format == "fp":
        return 2 * elems * dtype_bytes
    if kv_format == "int8":
        return 2 * (elems + scales)
    if kv_format == "sc":
        return 2 * (2 * elems + scales)
    raise ValueError(f"unknown kv_format {kv_format!r}")


def slots_per_gib(max_len: int, page_size: int, n_kv_heads: int,
                  head_dim: int, kv_format: str = "fp",
                  dtype_bytes: int = 4, n_layers: int = 1) -> float:
    """Full-length request slots one GiB of KV pool can hold.

    Pure accounting over :func:`kv_page_bytes` — the capacity headline
    BENCH_serving.json records per format (int8 >= 2x fp at any shape
    with Dh >= 8, since codes are 4x smaller and scales amortize over
    ``head_dim``)."""
    per_slot = (pages_needed(max_len, page_size)
                * kv_page_bytes(page_size, n_kv_heads, head_dim,
                                kv_format, dtype_bytes) * n_layers)
    return (1 << 30) / per_slot


def _pow2_up(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


def pad_pow2(n: int, lo: int = 1, hi: int | None = None) -> int:
    """Round ``n`` up to a power-of-two bucket size in ``[lo, hi]``.

    The result is ALWAYS a power of two >= n (the jit-bucket contract:
    non-pow2 buckets would mint a fresh trace per odd size).  ``lo`` is
    rounded up to a power of two; ``hi`` is clamped *down* to one (a
    non-pow2 cap like 6 cannot name a pow2 bucket).  ``hi`` is a soft
    cap: when no power of two <= hi can hold ``n`` (e.g. n=6, hi=6) the
    next power of two above ``n`` is returned anyway, so buffers sized
    by the bucket never under-allocate.
    """
    b = max(_pow2_up(lo), _pow2_up(n))
    if hi is not None:
        hi_pow = 1 << max(hi, 1).bit_length() - 1       # pow2 floor of hi
        b = min(b, max(hi_pow, _pow2_up(n)))
    return b


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages.

    Page 0 (``TRASH_PAGE``) is reserved at construction and never
    allocated.  ``alloc`` is all-or-nothing: it either returns ``n``
    distinct pages or ``None`` (so admission can fall back to waiting /
    preemption without partial bookkeeping).
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        # LIFO free list: recently-freed pages are reused first, which
        # keeps the hot working set of physical pages small
        self._free = list(range(num_pages - 1, 0, -1))
        self._allocated: set[int] = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"double free / foreign page {p}")
            self._allocated.discard(p)
            self._free.append(p)


@dataclass
class PageTable:
    """One request's logical->physical page mapping."""
    page_size: int
    pages: list[int] = field(default_factory=list)

    @property
    def capacity(self) -> int:
        return len(self.pages) * self.page_size

    def ensure(self, length: int, allocator: PageAllocator) -> bool:
        """Grow the table to hold ``length`` tokens.  Returns False (table
        unchanged) when the pool can't supply the missing pages."""
        need = pages_needed(length, self.page_size) - len(self.pages)
        if need <= 0:
            return True
        got = allocator.alloc(need)
        if got is None:
            return False
        self.pages.extend(got)
        return True

    def release(self, allocator: PageAllocator) -> None:
        allocator.free(self.pages)
        self.pages = []

    def padded(self, width: int) -> np.ndarray:
        """Physical ids padded with the trash page to ``width`` entries."""
        if len(self.pages) > width:
            raise ValueError(f"table has {len(self.pages)} pages > "
                             f"bucket width {width}")
        out = np.full((width,), TRASH_PAGE, np.int32)
        out[:len(self.pages)] = self.pages
        return out

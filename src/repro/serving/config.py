"""Typed, validated construction surface for :class:`ServeEngine`.

The engine grew eleven constructor knobs across six PRs (slots, paging,
chunking, datapath, two kernel backends, prefill mode, mesh rules, and
now the KV storage format) with the cross-field rules scattered through
``__init__``.  :class:`EngineConfig` is the single home for all of them:
a frozen dataclass carrying every serving knob, with EVERY validation —
per-field domains and cross-field compatibility alike — in
:meth:`EngineConfig.validate`.

``ServeEngine(params, cfg, **kwargs)`` still works: the old kwargs are a
thin shim that builds an ``EngineConfig`` and delegates, so the
dataclass is the single construction path either way.  New code should
say what it means::

    from repro.serving import EngineConfig, ServeEngine

    config = EngineConfig(max_slots=8, page_size=16, datapath="sc_int",
                          kv_format="int8")
    eng = ServeEngine.from_config(params, cfg, config)

Validation rules (each raises ``ValueError`` with a pointed message;
tests/test_kv_format.py exercises every one):

* ``max_slots >= 1``; ``max_len >= 2`` (a servable request is >= 1
  prompt token + 1 generated token).
* ``page_size`` is a power of two (the engine's pow2 bucket math and
  ``pad_pow2`` contracts assume it).
* ``num_pages`` is ``None`` (auto: full residency) or >= 2 (the pool
  reserves page 0 as the trash page).
* ``prefill_chunk >= 1``.
* ``datapath`` in :data:`DATAPATHS`; ``kv_format`` in
  :data:`~repro.core.kv_quant.KV_FORMATS`.
* ``kv_format="sc"`` requires an SC datapath (``sc_int`` /
  ``sc_int_approx``): the whole point of the SC-coded cache is keeping
  K/V on the SC number system end to end — pairing it with the
  fake-quant float path is a configuration error, not a degraded mode.
* ``bsn_backend`` / ``attn_backend`` in
  :data:`~repro.kernels.dispatch.BACKENDS` or ``None`` (auto).
* ``prefill_mode`` is ``"chunked"`` or ``"exact"`` (debug oracle).
* ``mesh_rules`` requires ``attn_backend`` in ``(None, "reference")`` —
  the paged Pallas kernel is a single-device program; the mesh path
  serves the constrained reference.
* ``draft_len >= 1`` (a speculative round must draft something).
* ``spec_decode`` requires a target datapath other than
  ``sc_int_approx`` — the drafter IS ``sc_int_approx``, so drafting for
  an approximate target verifies a model against itself (a no-op that
  silently doubles the compute); it's a configuration error.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

from repro.core.kv_quant import KV_FORMATS
from repro.distributed.sharding import MeshRules
from repro.kernels.dispatch import BACKENDS

__all__ = ["DATAPATHS", "EngineConfig"]

DATAPATHS = ("qat", "sc_int", "sc_int_approx")


@dataclass(frozen=True)
class EngineConfig:
    """Every serving knob of :class:`~repro.serving.ServeEngine`.

    Defaults reproduce the historical kwarg defaults exactly.
    """
    max_slots: int = 4
    max_len: int = 256
    page_size: int = 16
    num_pages: int | None = None
    prefill_chunk: int = 64
    datapath: str = "qat"
    kv_format: str = "fp"
    bsn_backend: str | None = None
    attn_backend: str | None = None
    prefill_mode: str = "chunked"
    mesh_rules: MeshRules | None = None
    spec_decode: bool = False
    draft_len: int = 4

    def validate(self) -> "EngineConfig":
        """Raise ``ValueError`` on the first violated rule; return self
        so construction sites can chain ``EngineConfig(...).validate()``."""
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2 (one prompt token + "
                             f"one generated token), got {self.max_len}")
        if self.page_size < 1 or self.page_size & (self.page_size - 1):
            raise ValueError(f"page_size must be a power of two, "
                             f"got {self.page_size}")
        if self.num_pages is not None and self.num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is the "
                             f"reserved trash page), got {self.num_pages}")
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {self.prefill_chunk}")
        if self.datapath not in DATAPATHS:
            raise ValueError(f"datapath must be one of {DATAPATHS}, "
                             f"got {self.datapath!r}")
        if self.kv_format not in KV_FORMATS:
            raise ValueError(f"kv_format must be one of {KV_FORMATS}, "
                             f"got {self.kv_format!r}")
        if self.kv_format == "sc" and self.datapath == "qat":
            raise ValueError(
                "kv_format='sc' stores the cache in the SC coding "
                "(thermometer + pow2 residual) and pairs with the SC "
                "datapaths only — use datapath='sc_int' or "
                "'sc_int_approx', or kv_format='int8'/'fp' with 'qat'")
        if self.bsn_backend is not None \
                and self.bsn_backend not in BACKENDS:
            raise ValueError(f"bsn_backend must be one of {BACKENDS} or "
                             f"None (auto), got {self.bsn_backend!r}")
        if self.attn_backend is not None \
                and self.attn_backend not in BACKENDS:
            raise ValueError(f"attn_backend must be one of {BACKENDS} or "
                             f"None (auto), got {self.attn_backend!r}")
        if self.prefill_mode not in ("chunked", "exact"):
            raise ValueError(f"prefill_mode must be 'chunked' or 'exact' "
                             f"(debug oracle), got {self.prefill_mode!r}")
        if self.mesh_rules is not None \
                and self.attn_backend not in (None, "reference"):
            raise ValueError(
                "mesh-sharded serving runs the constrained reference "
                "attention (the paged Pallas kernel is a single-device "
                f"program) — drop attn_backend={self.attn_backend!r} or "
                "the mesh_rules")
        if self.draft_len < 1:
            raise ValueError(f"draft_len must be >= 1 (a speculative "
                             f"round drafts at least one token), "
                             f"got {self.draft_len}")
        if self.spec_decode and self.datapath == "sc_int_approx":
            raise ValueError(
                "spec_decode drafts on the sc_int_approx datapath and "
                "verifies on the request's target datapath — a "
                "datapath='sc_int_approx' target makes drafter == "
                "verifier, a no-op that doubles compute; use "
                "datapath='qat' or 'sc_int'")
        return self

    def replace(self, **changes) -> "EngineConfig":
        import dataclasses
        return dataclasses.replace(self, **changes)

    @classmethod
    def field_names(cls) -> tuple[str, ...]:
        return tuple(f.name for f in fields(cls))

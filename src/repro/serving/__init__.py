"""Serving: continuous-batching engine over the zoo's prefill/decode."""

from .engine import Request, ServeEngine

__all__ = ["ServeEngine", "Request"]

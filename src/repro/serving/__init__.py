"""Serving: paged-KV continuous-batching engine over the zoo (see README.md)."""

from .engine import Request, ServeEngine, sequential_generate
from .paging import PageAllocator, PageTable
from .sampling import SamplingParams

__all__ = ["ServeEngine", "Request", "SamplingParams",
           "sequential_generate", "PageAllocator", "PageTable"]

"""Serving: paged-KV continuous-batching engine over the zoo (see README.md)."""

from .engine import Request, ServeEngine, sequential_generate
from .paging import PageAllocator, PageTable

__all__ = ["ServeEngine", "Request", "sequential_generate",
           "PageAllocator", "PageTable"]

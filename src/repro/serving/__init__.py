"""Serving: paged-KV continuous-batching engine over the zoo (see README.md)."""

from .config import DATAPATHS, EngineConfig
from .engine import Request, ServeEngine, sequential_generate
from .paging import (PageAllocator, PageTable, kv_page_bytes,
                     slots_per_gib)
from .sampling import SamplingParams

__all__ = ["ServeEngine", "Request", "SamplingParams", "EngineConfig",
           "DATAPATHS", "sequential_generate", "PageAllocator",
           "PageTable", "kv_page_bytes", "slots_per_gib"]

"""Continuous-batching serve engine v2: paged KV cache, batched decode.

Execution model (vLLM-style, scaled to this zoo):

* **Paged KV.**  Attention KV lives in a flat pool of fixed-size pages
  shared by every request; a host-side free-list allocator
  (serving/paging.py) hands pages to requests and the device code
  gathers/scatters through per-request page tables
  (models/attention.py).  HBM cost is proportional to *tokens actually
  held*, not ``max_slots x max_len``, and admission never copies or
  re-layouts a cache — prefill writes the same pages decode reads.
* **One batched decode step.**  Every engine step runs ALL active slots
  through a single jitted ``paged_decode_step`` — one period-scan
  forward for the whole batch, mixed progress handled by per-slot
  lengths/page tables.  Recurrent mixers (mamba/rwkv) keep per-slot
  state rows gathered/scattered by slot id inside the same step.
* **Chunked prefill — one path for every arch.**  Admitted requests
  prefill as one padded batch, chunk by chunk, directly into the page
  pools (``paged_prefill``).  Attention positions scatter whole K/V
  pages; recurrent positions (mamba/rwkv6) thread chunk-resumable state
  (conv tail + SSM/WKV state + token shifts) across chunk boundaries
  and scatter the final carry into their per-slot rows, all inside the
  same jitted call.  The recurrence runs per-token during prefill, so
  any chunk size reproduces the exact-length result bit for bit —
  order-exactness is preserved, it no longer costs a second datapath.
  ``prefill_mode="exact"`` keeps the old per-request exact-length
  fallback alive as a DEBUG ORACLE only.
* **Bucketed shapes.**  The decode step is traced per (slot-bucket,
  page-bucket) — both padded to powers of two — so jax recompiles only
  when a bucket boundary is crossed, not on every admission/eviction.
  Padded lanes point at the scratch state row and the trash page; they
  cost FLOPs, never correctness.

* **Mesh-sharded decode (tensor parallel).**  ``ServeEngine(mesh_rules=
  launch.mesh.serving_rules(mesh))`` shards params with the serving
  layout (column-parallel projections over ``"model"``, whole experts
  per device via ``moe_spec(serving=True)``), the KV page pools over
  their KV-head axis, and recurrent state rows over their channel axis;
  the jitted steps trace under the rules so GSPMD keeps weights
  resident and moves only the (tiny) decode activations.  Host-side
  paging/slot bookkeeping never sees the mesh.  The layout shards
  output channels only — never a contraction dim — because the SC
  accumulators (exact and approximate BSN) are per-output-channel
  units: each channel's K-term accumulation stays device-local, so
  mesh-on decode is token-identical to mesh-off (and to
  ``sequential_generate``) on every datapath.  With ``mesh_rules=None``
  nothing here activates and behavior is exactly single-device.

* **Seeded sampling.**  Each request carries :class:`SamplingParams`
  (temperature / top-k / top-p / min-p / seed; ``temperature == 0`` is
  greedy, the default).  The controls are packed into flat per-lane
  tensors and the categorical draw happens INSIDE the jitted decode /
  prefill steps (serving/sampling.py) — one traced step still advances
  the whole batch, bucketed shapes unchanged, no host round-trip.  The
  per-request PRNG key is a pure function of ``(seed, position)``, so
  batched, preempted-and-resumed, mesh-sharded and
  ``sequential_generate`` decode all draw identical tokens.  Whether a
  batch samples at all is a STATIC jit flag: all-greedy batches compile
  the plain argmax step (zero sampler compute — the default workload
  costs what the pre-sampling engine cost).

Datapath: ``datapath="qat"`` serves the fake-quant QAT forward;
``"sc_int"`` re-quantizes every projection on the fly and runs the
silicon-equivalent int8 x ternary -> int32 path
(``core.sc_layers.sc_linear_int_from_qat``); ``"sc_int_approx"``
additionally routes the accumulation through the paper's approximate
BSN adder, which dispatches to the fused Pallas kernel via
kernels/dispatch.  As in v1, every traced entry point runs inside
``backend_scope(bsn_backend)`` — dispatch decisions are made at trace
time, so the scope must surround the *first* (tracing) call.

Attention backend: paged decode and chunked prefill route their
attention through the same dispatch module — ``attn_backend=None``
(auto) serves the flash-decoding Pallas kernel
(kernels/paged_attention.py; interpret mode off-TPU), ``"reference"``
pins the XLA gather/scatter oracle.  ``attn_backend_scope`` wraps the
traced calls exactly like the BSN scope.  Under ``mesh_rules`` the
engine always serves the constrained reference (the kernel is a
single-device program; KV heads stay device-local over "model", so
mesh-on output is token-identical to the kernel path) and pinning a
pallas backend is rejected.
"""

from __future__ import annotations

import contextlib
import itertools
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_quant import kv_quant
from repro.distributed.sharding import MeshRules, mesh_rules, shard_tree
from repro.kernels import dispatch as kernel_dispatch
from repro.models import (decode_step, gather_state_rows, init_paged_cache,
                          paged_cache_specs, paged_decode_step,
                          paged_prefill, paged_verify_step, param_specs,
                          prefill, scatter_state_rows,
                          select_state_snapshot, supports_paged_prefill)

from .config import DATAPATHS, EngineConfig
from .paging import (TRASH_PAGE, PageAllocator, PageTable, pad_pow2,
                     pages_needed)
from .sampling import (SamplingParams, greedy_tokens, pack_sampling,
                       sample_tokens, speculative_accept, token_logprobs)

__all__ = ["Request", "SamplingParams", "ServeEngine", "EngineConfig",
           "DATAPATHS", "sequential_generate"]


def _cfg_for_datapath(cfg: ModelConfig, datapath: str) -> ModelConfig:
    if datapath not in DATAPATHS:
        raise ValueError(f"datapath must be one of {DATAPATHS}, "
                         f"got {datapath!r}")
    if datapath == "qat" or not cfg.quant.enabled:
        return cfg
    import dataclasses
    q = dataclasses.replace(cfg.quant, mode="sc_int",
                            int_approx=(datapath == "sc_int_approx"))
    return cfg.scaled(quant=q)


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    generated: list[int] = field(default_factory=list)
    done: bool = False
    # one dict per generated token when sampling.logprobs > 0 (else
    # stays empty): {"logprob": float, "top": [(token, logprob), ...]}
    # with the top list cropped to sampling.logprobs entries, scored
    # under the distribution the token was drawn from (see
    # sampling.token_logprobs)
    logprobs: list[dict] = field(default_factory=list)
    # engine internals
    _table: PageTable | None = field(default=None, repr=False)
    _len: int = field(default=0, repr=False)      # tokens held in cache


class ServeEngine:
    """Construct with :meth:`from_config` (an :class:`EngineConfig` is
    the single validated construction path); the keyword signature below
    is the back-compat shim — it builds the same ``EngineConfig`` and
    delegates, so both spellings hit identical validation."""

    def __init__(self, params, cfg: ModelConfig, max_slots: int = 4,
                 max_len: int = 256, bsn_backend: str | None = None,
                 page_size: int = 16, num_pages: int | None = None,
                 prefill_chunk: int = 64, datapath: str = "qat",
                 mesh_rules: MeshRules | None = None,
                 prefill_mode: str = "chunked",
                 attn_backend: str | None = None,
                 kv_format: str = "fp",
                 spec_decode: bool = False,
                 draft_len: int = 4,
                 config: EngineConfig | None = None):
        assert not cfg.is_encoder, "encoders are served via forward()"
        if config is None:
            config = EngineConfig(
                max_slots=max_slots, max_len=max_len, page_size=page_size,
                num_pages=num_pages, prefill_chunk=prefill_chunk,
                datapath=datapath, kv_format=kv_format,
                bsn_backend=bsn_backend, attn_backend=attn_backend,
                prefill_mode=prefill_mode, mesh_rules=mesh_rules,
                spec_decode=spec_decode, draft_len=draft_len)
        config.validate()
        self.config = config
        mesh_rules = config.mesh_rules
        self.prefill_mode = config.prefill_mode
        self.bsn_backend = config.bsn_backend
        self.attn_backend = config.attn_backend
        self.cfg = _cfg_for_datapath(cfg, config.datapath)
        self.datapath = config.datapath
        self.kv_format = config.kv_format
        # speculative decoding: draft on the cheap approximate-BSN
        # datapath, verify on the request's target datapath (self.cfg).
        # cfg_draft shares the SAME params pytree — the datapaths are
        # one model at three fidelities — so spec costs no extra weights.
        self.spec_decode = config.spec_decode
        self.draft_len = config.draft_len
        self.cfg_draft = _cfg_for_datapath(cfg, "sc_int_approx")
        self._spec_rounds = self._spec_draft_tokens = 0
        self._spec_accepted = self._spec_emitted = 0
        self.max_slots, self.max_len = config.max_slots, config.max_len
        self.page_size = config.page_size
        self.max_pages = pages_needed(config.max_len, config.page_size)
        num_pages = config.num_pages
        if num_pages is None:
            # full residency for every slot + the reserved trash page
            num_pages = config.max_slots * self.max_pages + 1
        self.allocator = PageAllocator(num_pages)
        self._rid = itertools.count()
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * config.max_slots
        cache = init_paged_cache(self.cfg, config.max_slots, num_pages,
                                 config.page_size, config.kv_format)
        self._chunk = pad_pow2(max(config.prefill_chunk, config.page_size))

        # Mesh-sharded serving (tensor-parallel decode): params take the
        # serving layout (every projection column-parallel over "model",
        # experts whole-per-device — see models/attention.attn_spec),
        # KV page pools and recurrent state rows shard their head /
        # channel axes (models/transformer.paged_cache_specs), and every
        # traced entry point runs under the rules so the
        # with_sharding_constraint annotations resolve.  All HOST
        # bookkeeping (allocator, page tables, slots) is device-count-
        # agnostic — it never sees the mesh.  With mesh_rules=None this
        # block is dead and behavior is exactly single-device.
        self.rules = mesh_rules
        if mesh_rules is not None:
            params = shard_tree(params, param_specs(self.cfg, serving=True),
                                mesh_rules)
            cache = shard_tree(cache,
                               paged_cache_specs(self.cfg, self.kv_format),
                               mesh_rules, logical=True)
        self.params = params
        self.cache = cache

        # jitted entry points.  The decode cache is donated: page pools
        # are updated in place across steps instead of copied.  Under a
        # mesh, output shardings are pinned to the input cache layout so
        # every step reuses one compiled variant per shape bucket
        # (donation stays clean, no sharding ping-pong).
        jit_kw, spec_jit_kw = {}, {}
        self._cache_sh = None
        if mesh_rules is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self._cache_sh = jax.tree.map(lambda a: a.sharding, self.cache)
            rep = NamedSharding(mesh_rules.mesh, P())
            # (tokens, cache, logprobs-or-()) — the sharding entries
            # broadcast as pytree prefixes, so the empty lp_k=0 tuple
            # contributes no leaves and the lp_k>0 triple pins replicated
            jit_kw["out_shardings"] = (rep, self._cache_sh, rep)
            spec_jit_kw["draft"] = {
                "out_shardings": (rep, self._cache_sh)}
            spec_jit_kw["verify"] = {
                "out_shardings": (rep, rep, self._cache_sh, rep)}
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,),
                               static_argnames=("do_sample", "lp_k"),
                               **jit_kw)
        self._prefill_batched = jax.jit(self._prefill_batched_fn,
                                        static_argnames=("chunk",
                                                         "do_sample",
                                                         "lp_k"),
                                        donate_argnums=(1,), **jit_kw)
        # The exact-prefill debug oracle is donation-EXEMPT by design
        # (analysis/contracts.audit_donation records the exemption): it
        # takes (params, batch) only and builds a fresh exact-length
        # cache, so there is no input cache buffer to alias an output
        # into — donating nothing is correct, not an oversight.
        self._prefill_exact = jax.jit(self._prefill_exact_fn,
                                      static_argnames=("do_sample",
                                                       "lp_k"))
        self._draft = jax.jit(self._draft_fn, donate_argnums=(1,),
                              static_argnames=("do_sample",),
                              **spec_jit_kw.get("draft", {}))
        self._verify = jax.jit(self._verify_fn, donate_argnums=(1,),
                               static_argnames=("do_sample", "lp_k"),
                               **spec_jit_kw.get("verify", {}))

    @classmethod
    def from_config(cls, params, cfg: ModelConfig,
                    config: EngineConfig) -> "ServeEngine":
        """The preferred construction path: every knob in one validated
        :class:`EngineConfig` (see serving/config.py for the rules)."""
        return cls(params, cfg, config=config)

    # -- traced bodies --------------------------------------------------
    #
    # The categorical draw lives INSIDE each traced body: the logits
    # never leave the device, and the ``samp`` tensors follow the lane
    # bucket shape so sampling adds zero retraces within a mode.  Draw
    # positions are the fold-in counters of the (seed, position)
    # streams — the decode step writes its input token at ``lengths``,
    # so the token it draws sits at sequence index ``lengths + 1``;
    # prefill draws the first generated token at index ``prompt_len``.
    #
    # ``do_sample`` is a STATIC flag, true iff some live lane has
    # temperature > 0: an all-greedy batch (the default workload)
    # compiles the plain argmax step with zero sampler compute — no
    # sorts, no RNG — exactly the pre-sampling engine.  Worst case this
    # doubles the compiled variants per shape bucket (greedy + sampled);
    # temperature=0 lanes inside a sampled batch take the in-trace
    # greedy branch of ``sample_tokens``, which is bit-identical, so
    # batch composition never changes anyone's tokens.

    # ``lp_k`` is the second static flag: the pow2-bucketed batch max of
    # SamplingParams.logprobs.  lp_k == 0 (the default workload, and the
    # only value the analysis gate traces) compiles the historical step
    # byte for byte — token_logprobs (log_softmax + top_k sorts) never
    # enters the jaxpr, which test_spec_decode pins via the dot-profile
    # snapshot.  The lp slot is an EMPTY tuple then, so output pytrees
    # and out_shardings stay aligned across both variants.

    def _decode_fn(self, params, cache, tokens, slot_ids, tables, lengths,
                   samp, *, do_sample, lp_k=0):
        logits, cache = paged_decode_step(params, cache, tokens,
                                          slot_ids, tables, lengths,
                                          self.cfg)
        nxt = sample_tokens(logits, lengths + 1, samp,
                            self.cfg.vocab_size) if do_sample \
            else greedy_tokens(logits, self.cfg.vocab_size)
        lp = token_logprobs(logits, nxt, samp, self.cfg.vocab_size,
                            lp_k) if lp_k else ()
        return nxt, cache, lp

    def _prefill_batched_fn(self, params, cache, tokens, tables, lens,
                            slot_ids, samp, *, chunk, do_sample, lp_k=0):
        logits, cache = paged_prefill(params, cache, tokens, tables,
                                      lens, self.cfg, chunk=chunk,
                                      slot_ids=slot_ids)
        nxt = sample_tokens(logits, lens, samp,
                            self.cfg.vocab_size) if do_sample \
            else greedy_tokens(logits, self.cfg.vocab_size)
        lp = token_logprobs(logits, nxt, samp, self.cfg.vocab_size,
                            lp_k) if lp_k else ()
        return nxt, cache, lp

    def _prefill_exact_fn(self, params, batch, samp, *, do_sample,
                          lp_k=0):
        logits, cache = prefill(params, batch, self.cfg)
        plen = logits.shape[1]                    # static: exact length
        pos = jnp.full((1,), plen, jnp.int32)
        tok = sample_tokens(logits[:, -1], pos, samp,
                            self.cfg.vocab_size) if do_sample \
            else greedy_tokens(logits[:, -1], self.cfg.vocab_size)
        lp = token_logprobs(logits[:, -1], tok, samp,
                            self.cfg.vocab_size, lp_k) if lp_k else ()
        return tok[0], cache, lp

    # -- speculative decoding (draft on sc_int_approx, verify on the
    #    target datapath) ------------------------------------------------
    #
    # One spec round = TWO jit dispatches for up to draft_len + 1
    # committed tokens:
    #
    # 1. _draft_fn: an in-jit scan of `draft_len` single-token decode
    #    steps on cfg_draft (the paper's approximate-BSN path), sharing
    #    the target's params AND paged cache.  The draft's K/V writes at
    #    positions len..len+k-1 are dead (the verify scatter overwrites
    #    every one before any read can see them: they sit past the
    #    committed length until then), and the recurrent state rows are
    #    checkpointed before / restored after, so approximate arithmetic
    #    never leaks into target state.
    # 2. _verify_fn: ONE parallel multi-token target forward over the
    #    window [t0, d_1..d_k] (paged_verify_step), drawing the target
    #    token tau_t at every window position from the SAME
    #    (seed, position) Gumbel stream the draft used.  The accepted
    #    prefix is simply where draft == target (shared noise makes the
    #    classic accept/resample rule collapse to token equality), and
    #    the engine always emits TARGET draws — so spec-on output is
    #    bit-identical to spec-off by construction, not just equal in
    #    distribution.

    def _draft_fn(self, params, cache, tokens, slot_ids, tables, lengths,
                  samp, *, do_sample):
        rows0 = gather_state_rows(cache, slot_ids)

        def body(carry, t):
            cache, tok = carry
            logits, cache = paged_decode_step(params, cache, tok,
                                              slot_ids, tables,
                                              lengths + t, self.cfg_draft)
            nxt = sample_tokens(logits, lengths + 1 + t, samp,
                                self.cfg.vocab_size) if do_sample \
                else greedy_tokens(logits, self.cfg.vocab_size)
            return (cache, nxt), nxt

        (cache, _), drafts = jax.lax.scan(
            body, (cache, tokens),
            jnp.arange(self.draft_len, dtype=jnp.int32))
        cache = scatter_state_rows(cache, rows0, slot_ids)
        return jnp.moveaxis(drafts, 0, 1), cache          # (S, k)

    def _verify_fn(self, params, cache, tokens, drafts, slot_ids, tables,
                   lengths, samp, *, do_sample, lp_k=0):
        win = jnp.concatenate([tokens[:, None], drafts], axis=1)
        logits, cache, snaps = paged_verify_step(
            params, cache, win, slot_ids, tables, lengths, self.cfg)
        S, T, V = logits.shape
        flat = logits.reshape(S * T, V)
        # row (s, t) draws the token at sequence index lengths[s]+1+t —
        # the very fold-in counters non-speculative decode would use
        pos = (lengths[:, None] + 1
               + jnp.arange(T, dtype=jnp.int32)[None, :]).reshape(-1)
        sampf = {k: jnp.repeat(v, T) for k, v in samp.items()}
        tau = sample_tokens(flat, pos, sampf,
                            self.cfg.vocab_size) if do_sample \
            else greedy_tokens(flat, self.cfg.vocab_size)
        tau = tau.reshape(S, T)
        m = speculative_accept(drafts, tau[:, :T - 1])    # (S,)
        cache = scatter_state_rows(
            cache, select_state_snapshot(snaps, m), slot_ids)
        if lp_k:
            chosen, ids, lps = token_logprobs(
                flat, tau.reshape(-1), sampf, self.cfg.vocab_size, lp_k)
            lp = (chosen.reshape(S, T), ids.reshape(S, T, lp_k),
                  lps.reshape(S, T, lp_k))
        else:
            lp = ()
        return tau, m, cache, lp

    @contextlib.contextmanager
    def _scope(self):
        """Every traced call runs here: BSN and paged-attention backend
        dispatch happens at trace time, and the mesh rules must be
        active so logical-axis constraints resolve (all are no-ops when
        unset)."""
        with kernel_dispatch.backend_scope(self.bsn_backend), \
                kernel_dispatch.attn_backend_scope(self.attn_backend):
            if self.rules is None:
                yield
            else:
                with mesh_rules(self.rules):
                    yield

    # -- submission -----------------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None,
               sampling: SamplingParams | None = None) -> int:
        if len(prompt) == 0:
            # an empty prompt would reach prefill as a (1, 0) token batch
            # and fail deep inside the model (rope/scan over S=0);
            # sequential_generate has no first-token logit either — fail
            # loudly at the API boundary instead.
            raise ValueError("empty prompt: need at least one token")
        if max_new_tokens < 1:
            # a <= 0 budget used to be admitted anyway: _check_done only
            # runs AFTER a token lands, so the request produced one token
            # the caller never asked for (and the slot/pages were held
            # for a full prefill + decode round-trip meanwhile)
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        if len(prompt) > self.max_len - 1:
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds "
                             f"max_len={self.max_len}")
        need = pages_needed(len(prompt) + 1, self.page_size)
        if need > self.allocator.num_pages - 1:
            # would never admit, not even with an empty pool
            raise ValueError(f"prompt needs {need} pages but the pool "
                             f"holds {self.allocator.num_pages - 1}")
        r = Request(next(self._rid), list(prompt), max_new_tokens, eos_id,
                    sampling if sampling is not None else SamplingParams())
        self.queue.append(r)
        return r.rid

    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # -- admission ------------------------------------------------------
    def _admit(self):
        group: list[tuple[int, Request]] = []
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.queue[0]
            table = PageTable(self.page_size)
            # reserve prompt pages + the first decode write up front
            if not table.ensure(len(req.prompt) + 1, self.allocator):
                break                         # pool pressure: wait
            self.queue.pop(0)
            req._table, req._len = table, len(req.prompt)
            self.slots[slot] = req
            group.append((slot, req))
        if not group:
            return
        if supports_paged_prefill(self.cfg) \
                and self.prefill_mode == "chunked":
            self._prefill_group(group)
        else:
            for _, r in group:
                self._prefill_one(r)

    def _prefill_group(self, group: list[tuple[int, Request]]):
        """Batched chunked prefill: one padded (G, L) bucket.  Like the
        decode step, every shape is a pow2 bucket (group size, prompt
        length, table width) so admission retraces only on bucket
        changes; padded lanes are all-trash tables + zero lengths +
        the scratch state row."""
        reqs = [r for _, r in group]
        plens = [len(r.prompt) for r in reqs]
        G = pad_pow2(len(reqs), hi=self.max_slots)
        L = pad_pow2(max(plens), lo=self.page_size)
        chunk = min(self._chunk, L)
        width = pad_pow2(max(L // self.page_size,
                             max(len(r._table.pages) for r in reqs)))
        tokens = np.zeros((G, L), np.int32)
        tables = np.full((G, width), TRASH_PAGE, np.int32)
        lens = np.zeros((G,), np.int32)
        slot_ids = np.full((G,), self.max_slots, np.int32)   # scratch row
        for g, (slot, r) in enumerate(group):
            tokens[g, :plens[g]] = r.prompt
            tables[g] = r._table.padded(width)
            lens[g] = plens[g]
            slot_ids[g] = slot
        samp = pack_sampling([r.sampling for r in reqs], pad_to=G)
        do_sample = any(not r.sampling.greedy for r in reqs)
        lp_k = self._lp_bucket(reqs)
        with self._scope():
            nxt, self.cache, lp = self._prefill_batched(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(tables), jnp.asarray(lens),
                jnp.asarray(slot_ids), samp, chunk=chunk,
                do_sample=do_sample, lp_k=lp_k)
        lp = jax.device_get(lp) if lp_k else None
        for g, r in enumerate(reqs):
            r.generated.append(int(nxt[g]))
            if lp is not None and r.sampling.logprobs > 0:
                r.logprobs.append(self._lp_record(
                    lp[0][g], lp[1][g], lp[2][g], r.sampling.logprobs))
            self._check_done(r)

    def _check_done(self, r: Request):
        """THE stop rule (the only copy: prefill and decode both route
        here).  Mirrors ``sequential_generate``'s loop condition — it
        keeps decoding while ``len(gen) < max_new_tokens and length <
        max_len - 1 and gen[-1] != eos`` — so a request stops after the
        token that makes any of the three false."""
        hit_eos = r.eos_id is not None and r.generated \
            and r.generated[-1] == r.eos_id
        if hit_eos or len(r.generated) >= r.max_new_tokens \
                or r._len >= self.max_len - 1:
            r.done = True

    def _prefill_one(self, req: Request):
        """Exact-length per-request prefill + eager scatter into the
        paged layout.  No longer any arch's hot path: the chunked paged
        prefill is order-exact for recurrent mixers too.  Kept as (a)
        the ``prefill_mode="exact"`` DEBUG ORACLE — it reproduces the
        chunked path token for token, which the tests assert — and (b)
        the route for frontend archs, whose inputs aren't token
        prompts (``supports_paged_prefill`` is False)."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        samp = pack_sampling([req.sampling])
        lp_k = self._lp_bucket([req])
        with self._scope():
            tok, cache_one, lp = self._prefill_exact(
                self.params, {"tokens": toks}, samp,
                do_sample=not req.sampling.greedy, lp_k=lp_k)
        self._scatter_prefill(req, cache_one)
        req.generated.append(int(tok))
        if lp_k and req.sampling.logprobs > 0:
            lp = jax.device_get(lp)
            req.logprobs.append(self._lp_record(
                lp[0][0], lp[1][0], lp[2][0], req.sampling.logprobs))
        self._check_done(req)

    def _scatter_prefill(self, req: Request, cache_one: dict):
        """Write a (B=1, exact-length) prefill cache into pages/rows.

        Compressed caches quantize here too (``kv_quant`` on the dense
        K/V rows, then pad + page-scatter codes/scales/residuals with the
        same indices): quantization is per-position and elementwise, so
        this exact oracle produces bit-identical pool contents to the
        chunked path's quantize-on-scatter."""
        plen = len(req.prompt)
        page = self.page_size
        npg = pages_needed(plen, page)
        phys = jnp.asarray(req._table.pages[:npg], jnp.int32)
        row = self.slots.index(req)
        periods = dict(self.cache["periods"])
        for i in range(len(self.cfg.period)):
            key = f"p{i}"
            entry = dict(periods[key])
            one = cache_one["periods"][key]
            for name, val in one.items():       # leaves: (P, 1, ...)
                if name in ("k", "v"):          # (P, 1, plen, Hkv, Dh)
                    qd = kv_quant(val[:, 0], self.kv_format)
                    stores = {name + "_pages": qd["q"]}
                    if "scale" in qd:
                        stores[name + "_scale"] = qd["scale"]
                    if "resid" in qd:
                        stores[name + "_resid"] = qd["resid"]
                    for pool_name, sv in stores.items():
                        pads = [(0, 0)] * sv.ndim
                        pads[1] = (0, npg * page - plen)
                        sv = jnp.pad(sv, pads)
                        sv = sv.reshape(sv.shape[0], npg, page,
                                        *sv.shape[2:])
                        pool = entry[pool_name]
                        entry[pool_name] = pool.at[:, phys].set(
                            sv.astype(pool.dtype))
                else:                           # recurrent state rows
                    entry[name] = jax.tree.map(
                        lambda full, o: full.at[:, row].set(
                            o[:, 0].astype(full.dtype)),
                        entry[name], val)
            periods[key] = entry
        cache = {"periods": periods}
        if self._cache_sh is not None:
            # the eager scatters above leave GSPMD-inferred shardings on
            # the touched leaves; re-pin to the init-time layout so the
            # next decode step's donation (out_shardings pinned at
            # __init__) stays clean instead of copying the whole cache
            cache = jax.device_put(cache, self._cache_sh)
        self.cache = cache

    # -- logprobs -------------------------------------------------------
    @staticmethod
    def _lp_bucket(reqs) -> int:
        """The static top-k width traced into the step: the batch max of
        SamplingParams.logprobs, pow2-padded so requests asking for 3 vs
        4 top entries share a compiled variant.  0 (nobody asked) keeps
        the historical step — no sampler/sort compute in the jaxpr."""
        m = max((r.sampling.logprobs for r in reqs), default=0)
        return pad_pow2(m) if m else 0

    @staticmethod
    def _lp_record(chosen, ids, lps, n: int) -> dict:
        """Crop one lane's device logprob row to the request's own
        ``logprobs=N`` ask (the traced width is the batch bucket)."""
        return {"logprob": float(chosen),
                "top": [(int(t), float(p))
                        for t, p in zip(ids[:n], lps[:n])]}

    # -- stepping -------------------------------------------------------
    def _packed_sampling(self, active: list[int], Sb: int) -> dict:
        """Per-lane sampling tensors for the decode step.  They are
        constant for a given lane composition, so re-pack (5 host
        builds + uploads) only when admission/eviction/preemption
        changes which request rides which lane — not every token."""
        key = (tuple(self.slots[i].rid for i in active), Sb)
        if getattr(self, "_samp_key", None) != key:
            self._samp_key = key
            self._samp_packed = pack_sampling(
                [self.slots[i].sampling for i in active], pad_to=Sb)
        return self._samp_packed

    def _grow_or_preempt(self, active: list[int]) -> list[int]:
        """Make sure every active slot can take one more token; preempt
        the youngest request (free pages, requeue for re-prefill) under
        pool pressure.  Decode is deterministic — greedy trivially, and
        seeded sampling because its PRNG streams are keyed by (seed,
        position) only — so a preempted request regenerates the same
        tokens after re-admission."""
        for i in list(active):
            r = self.slots[i]
            if r is None or r.done:   # preempted / finished at prefill
                continue
            while not r._table.ensure(r._len + 1, self.allocator):
                victims = sorted((j for j in active if j != i),
                                 key=lambda j: self.slots[j].rid)
                if not victims:
                    # nothing left to evict: finish truncated
                    r.done = True
                    break
                v = victims[-1]
                vr = self.slots[v]
                vr._table.release(self.allocator)
                vr._table, vr._len = None, 0
                vr.generated = []
                vr.logprobs = []
                self.queue.insert(0, vr)
                self.slots[v] = None
                active.remove(v)
        return [i for i in active
                if self.slots[i] is not None and not self.slots[i].done]

    def _sweep_done(self, done: list[Request]) -> None:
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                r._table.release(self.allocator)
                r._table = None
                done.append(r)
                self.slots[i] = None

    def _step_batch(self, active: list[int]):
        """The shared (Sb, maxp) pow2-bucketed lane tensors every decode
        variant (plain and speculative) feeds from."""
        Sb = pad_pow2(len(active), hi=self.max_slots)
        maxp = pad_pow2(max(len(self.slots[i]._table.pages)
                            for i in active))
        tokens = np.zeros((Sb,), np.int32)
        slot_ids = np.full((Sb,), self.max_slots, np.int32)  # scratch
        tables = np.full((Sb, maxp), TRASH_PAGE, np.int32)
        lengths = np.zeros((Sb,), np.int32)
        for lane, i in enumerate(active):
            r = self.slots[i]
            tokens[lane] = r.generated[-1]
            slot_ids[lane] = i
            tables[lane] = r._table.padded(maxp)
            lengths[lane] = r._len
        samp = self._packed_sampling(active, Sb)
        do_sample = any(not self.slots[i].sampling.greedy for i in active)
        lp_k = self._lp_bucket([self.slots[i] for i in active])
        return (jnp.asarray(tokens), jnp.asarray(slot_ids),
                jnp.asarray(tables), jnp.asarray(lengths), samp,
                do_sample, lp_k)

    def _ensure_spec_window(self, active: list[int]) -> bool:
        """All-or-nothing capacity check for ONE speculative round: every
        active lane must fit ``draft_len + 1`` more cache positions
        (window writes land at ``_len .. _len + draft_len``) and grow its
        page table WITHOUT preemption.  On any failure the step falls
        back to plain one-token decode — speculation is an optimization
        and must never evict work the plain path would have kept.  (A
        lane that grew some pages before a later lane failed keeps them:
        ``ensure`` is monotone and the pages stay owned by its table,
        used by the very next +1 growth or released at completion.)"""
        k = self.draft_len
        if any(self.slots[i]._len + k > self.max_len - 1 for i in active):
            return False
        return all(self.slots[i]._table.ensure(
            self.slots[i]._len + k + 1, self.allocator) for i in active)

    def _spec_round(self, active: list[int]):
        """Draft ``draft_len`` tokens on sc_int_approx, verify in one
        parallel target step, commit the accepted prefix + bonus token.
        Emitted tokens are always the target's own (seed, position) draws
        (see the traced-body comment), so requests cannot tell this path
        from plain decode — only the step count can."""
        tokens, slot_ids, tables, lengths, samp, do_sample, lp_k = \
            self._step_batch(active)
        with self._scope():
            drafts, self.cache = self._draft(
                self.params, self.cache, tokens, slot_ids, tables,
                lengths, samp, do_sample=do_sample)
            tau, m, self.cache, lp = self._verify(
                self.params, self.cache, tokens, drafts, slot_ids,
                tables, lengths, samp, do_sample=do_sample, lp_k=lp_k)
        tau, m = np.asarray(tau), np.asarray(m)
        lp = jax.device_get(lp) if lp_k else None
        self._spec_rounds += 1
        self._spec_draft_tokens += self.draft_len * len(active)
        for lane, i in enumerate(active):
            r = self.slots[i]
            self._spec_accepted += int(m[lane])
            for j in range(int(m[lane]) + 1):
                r.generated.append(int(tau[lane, j]))
                r._len += 1
                if lp is not None and r.sampling.logprobs > 0:
                    r.logprobs.append(self._lp_record(
                        lp[0][lane, j], lp[1][lane, j], lp[2][lane, j],
                        r.sampling.logprobs))
                self._spec_emitted += 1
                self._check_done(r)
                if r.done:
                    break

    @property
    def spec_stats(self) -> dict:
        """Speculative-decoding counters since construction.
        ``acceptance_rate`` = accepted drafts / drafted tokens;
        ``tokens_per_round`` = committed tokens per verify forward — the
        verifier-side speedup (each round costs ONE target-model
        multi-token step, so this is the decode-steps-saved factor on
        hardware where the drafter is cheap)."""
        return {
            "rounds": self._spec_rounds,
            "draft_tokens": self._spec_draft_tokens,
            "accepted_tokens": self._spec_accepted,
            "emitted_tokens": self._spec_emitted,
            "acceptance_rate": (self._spec_accepted
                                / max(self._spec_draft_tokens, 1)),
            "tokens_per_round": (self._spec_emitted
                                 / max(self._spec_rounds, 1)),
        }

    def step(self) -> list[Request]:
        """Admit + ONE batched decode step (speculative round when
        ``spec_decode`` is on and every lane has window headroom).
        Returns finished requests."""
        self._admit()
        done: list[Request] = []
        # requests finished at prefill free their pages BEFORE growth, so
        # they are never preemption victims and their pages count toward
        # this step's headroom
        self._sweep_done(done)
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if self.spec_decode and active \
                and self._ensure_spec_window(active):
            self._spec_round(active)
        else:
            active = self._grow_or_preempt(active)
            if active:
                tokens, slot_ids, tables, lengths, samp, do_sample, \
                    lp_k = self._step_batch(active)
                with self._scope():
                    nxt, self.cache, lp = self._decode(
                        self.params, self.cache, tokens, slot_ids,
                        tables, lengths, samp, do_sample=do_sample,
                        lp_k=lp_k)
                nxt = np.asarray(nxt)
                lp = jax.device_get(lp) if lp_k else None
                for lane, i in enumerate(active):
                    r = self.slots[i]
                    r.generated.append(int(nxt[lane]))
                    r._len += 1
                    if lp is not None and r.sampling.logprobs > 0:
                        r.logprobs.append(self._lp_record(
                            lp[0][lane], lp[1][lane], lp[2][lane],
                            r.sampling.logprobs))
                    self._check_done(r)
        self._sweep_done(done)          # decode-finished + truncated
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        out = []
        for _ in range(max_steps):
            out += self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return out


# ---------------------------------------------------------------------------
# sequential reference (the seed engine's execution model)
# ---------------------------------------------------------------------------

def _pad_prefill_cache(cache_one: dict, max_len: int) -> dict:
    def fit(path, one):
        names = [getattr(p, "key", None) for p in path]
        if names and names[-1] in ("k", "v") and one.ndim == 5:
            pad = [(0, 0)] * one.ndim
            pad[2] = (0, max_len - one.shape[2])
            one = jnp.pad(one, pad)
        return one
    return jax.tree_util.tree_map_with_path(fit, cache_one)


def sequential_generate(params, cfg: ModelConfig, prompts: list[list[int]],
                        max_new_tokens: int = 16, eos_id: int | None = None,
                        max_len: int = 256, bsn_backend: str | None = None,
                        datapath: str = "qat",
                        sampling: SamplingParams | list[SamplingParams]
                        | None = None,
                        kv_format: str = "fp",
                        page_size: int = 8) -> list[list[int]]:
    """Per-request prefill + one-token-at-a-time decode — the seed
    engine's per-slot execution model.

    This is the reference oracle: the batched paged engine must produce
    these tokens exactly (tests/test_paged_kv.py, test_sampling.py) and
    beat this loop's throughput (benchmarks/bench_serving.py).  Stop
    conditions mirror ``ServeEngine.step``.  ``sampling`` is one
    :class:`SamplingParams` for every prompt or a per-prompt list
    (default greedy); token picks route through the SAME
    ``sample_tokens`` the engine traces, at batch 1, with the same
    (seed, position) fold-in streams — position ``len(prompt) + n`` for
    the n-th generated token.

    ``kv_format="fp"`` runs the dense (un-paged) cache, bit-identical
    to the seed engine.  Compressed formats have no dense analogue (the
    codes live in page pools), so the oracle becomes a one-request-at-a-
    time PAGED loop: a private B=1 cache with an identity page table,
    one ``paged_prefill`` call, then per-token ``paged_decode_step`` —
    independent of the engine's allocator, bucketing, admission and
    batching (and of its ``page_size``: per-position quantization makes
    the codes page-layout-invariant), which is what makes the batched ==
    sequential differential meaningful for int8/sc too.
    """
    cfg = _cfg_for_datapath(cfg, datapath)
    sps = sampling if isinstance(sampling, list) \
        else [sampling] * len(prompts)
    if len(sps) != len(prompts):
        raise ValueError(f"sampling list has {len(sps)} entries for "
                         f"{len(prompts)} prompts")
    # None entries mean greedy, same as ServeEngine.submit(sampling=None)
    sps = [sp if sp is not None else SamplingParams() for sp in sps]
    if kv_format != "fp":
        return _paged_sequential_generate(
            params, cfg, prompts, sps, max_new_tokens, eos_id, max_len,
            bsn_backend, kv_format, page_size)
    # params are explicit jit ARGUMENTS, matching the engine's traced
    # entry points: closure-captured params constant-fold differently in
    # XLA, and on the fake-quant lattice that 1-ulp drift can flip exact
    # argmax ties — the differential theorem needs both sides compiled
    # under the same discipline.
    prefill_fn = jax.jit(lambda p, b: prefill(p, b, cfg))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    sample_fn = jax.jit(
        lambda lg, pos, sm: sample_tokens(lg, pos, sm, cfg.vocab_size))
    greedy_fn = jax.jit(lambda lg: greedy_tokens(lg, cfg.vocab_size))
    outs = []
    with kernel_dispatch.backend_scope(bsn_backend):
        for prompt, sp in zip(prompts, sps):
            samp = pack_sampling([sp])

            def pick(lg, t):
                # greedy requests skip the sampler entirely, mirroring
                # the engine's static do_sample split
                if sp.greedy:
                    return int(greedy_fn(lg)[0])
                return int(sample_fn(lg, jnp.asarray([t], jnp.int32),
                                     samp)[0])

            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            logits, cache = prefill_fn(params, {"tokens": toks})
            cache = _pad_prefill_cache(cache, max_len)
            length = len(prompt)
            gen = [pick(logits[:, -1], length)]
            while (len(gen) < max_new_tokens
                   and length < max_len - 1
                   and (eos_id is None or gen[-1] != eos_id)):
                tok = jnp.asarray([[gen[-1]]], jnp.int32)
                logits, cache = decode_fn(params, cache, tok)
                gen.append(pick(logits[:, 0], length + 1))
                length += 1
            outs.append(gen)
    return outs


@partial(jax.jit, static_argnames=("cfg", "chunk", "bsn_backend"))
def _oracle_paged_prefill(params, cache, tokens, tables, plen, slot_ids,
                          *, cfg: ModelConfig, chunk: int,
                          bsn_backend: str | None):
    """Module-level jit for the paged oracle's prefill, cached across
    prompts AND across ``sequential_generate`` calls — the per-prompt
    ``jax.jit(lambda ...)`` it replaces re-traced every single prompt
    (the retrace audit's first confirmed catch; see
    analysis/contracts.py).  Keyed on (cfg, chunk, backend) statics plus
    arg shapes; the BSN backend is static because dispatch decisions
    happen at trace time inside the scope, so each pinned backend must
    own its trace."""
    with kernel_dispatch.backend_scope(bsn_backend):
        return paged_prefill(params, cache, tokens, tables, plen, cfg,
                             chunk=chunk, slot_ids=slot_ids)


@partial(jax.jit, static_argnames=("cfg", "bsn_backend"))
def _oracle_paged_decode(params, cache, tok, slot_ids, tables, lengths,
                         *, cfg: ModelConfig, bsn_backend: str | None):
    """Module-level jit for the paged oracle's decode step (same caching
    rationale as :func:`_oracle_paged_prefill`)."""
    with kernel_dispatch.backend_scope(bsn_backend):
        return paged_decode_step(params, cache, tok, slot_ids, tables,
                                 lengths, cfg)


def _paged_sequential_generate(params, cfg: ModelConfig, prompts, sps,
                               max_new_tokens: int, eos_id: int | None,
                               max_len: int, bsn_backend: str | None,
                               kv_format: str,
                               page_size: int) -> list[list[int]]:
    """The B=1 paged oracle behind ``sequential_generate(kv_format=...)``:
    a private single-slot cache per request, identity page table (page
    ``j`` of the request lives at physical page ``j + 1``), one chunked
    ``paged_prefill`` covering the whole prompt, then one
    ``paged_decode_step`` per token.  No allocator, no bucketing, no
    admission — exactly the "one request at a time" semantics of the
    dense oracle, on the compressed pool layout."""
    assert supports_paged_prefill(cfg), \
        "compressed-KV sequential oracle needs token prompts"
    sample_fn = jax.jit(
        lambda lg, pos, sm: sample_tokens(lg, pos, sm, cfg.vocab_size))
    greedy_fn = jax.jit(lambda lg: greedy_tokens(lg, cfg.vocab_size))
    slot_ids = jnp.zeros((1,), jnp.int32)
    outs = []
    with kernel_dispatch.backend_scope(bsn_backend):
        for prompt, sp in zip(prompts, sps):
            samp = pack_sampling([sp])

            def pick(lg, t):
                if sp.greedy:
                    return int(greedy_fn(lg)[0])
                return int(sample_fn(lg, jnp.asarray([t], jnp.int32),
                                     samp)[0])

            # prompt pages + every decode write fit the identity table
            L = pad_pow2(max(len(prompt), page_size))
            maxp = max(pages_needed(max_len, page_size), L // page_size)
            cache = init_paged_cache(cfg, 1, maxp + 1, page_size,
                                     kv_format)
            tables = jnp.arange(1, maxp + 1, dtype=jnp.int32)[None, :]
            toks = np.zeros((1, L), np.int32)
            toks[0, :len(prompt)] = prompt
            plen = jnp.asarray([len(prompt)], jnp.int32)
            logits, cache = _oracle_paged_prefill(
                params, cache, jnp.asarray(toks), tables, plen, slot_ids,
                cfg=cfg, chunk=L, bsn_backend=bsn_backend)
            length = len(prompt)
            gen = [pick(logits, length)]
            while (len(gen) < max_new_tokens
                   and length < max_len - 1
                   and (eos_id is None or gen[-1] != eos_id)):
                tok = jnp.asarray([gen[-1]], jnp.int32)
                lengths = jnp.asarray([length], jnp.int32)
                logits, cache = _oracle_paged_decode(
                    params, cache, tok, slot_ids, tables, lengths,
                    cfg=cfg, bsn_backend=bsn_backend)
                gen.append(pick(logits, length + 1))
                length += 1
            outs.append(gen)
    return outs

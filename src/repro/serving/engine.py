"""Slot-based continuous batching engine.

vLLM-style structure scaled to this zoo: a fixed pool of ``max_slots``
sequence slots, each with its own KV/state cache position.  New requests
are prefillled individually and inserted into free slots; every engine
step runs ONE batched decode across all slots (per-slot positions via a
vmapped decode step), so mixed-progress sequences share each forward pass.

The big-mesh serve path (launch/serve.py, dry-run decode cells) uses the
uniform-position ``decode_step`` directly; this engine is the
request-level orchestration above it.

Kernel routing: the engine owns the dispatch policy for the SC
approximate adder (kernels/dispatch.py).  Every traced entry point
(prefill, the vmapped decode) runs inside ``backend_scope(bsn_backend)``,
so any ``core.bsn.approx_bsn`` / ``sc_linear_int_approx`` call in the
served model resolves to the fused Pallas kernel on TPU (interpret mode
elsewhere) by default, without the model naming a backend.  Pass
``bsn_backend="reference"`` to pin the pure-JAX oracle, e.g. when
A/B-ing kernel output in production.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kernel_dispatch
from repro.models import decode_step, init_cache, prefill

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, max_slots: int = 4,
                 max_len: int = 256, bsn_backend: str | None = None):
        assert not cfg.is_encoder, "encoders are served via forward()"
        if bsn_backend is not None \
                and bsn_backend not in kernel_dispatch.BACKENDS:
            raise ValueError(f"bsn_backend must be one of "
                             f"{kernel_dispatch.BACKENDS} or None (auto), "
                             f"got {bsn_backend!r}")
        self.bsn_backend = bsn_backend
        self.params, self.cfg = params, cfg
        self.max_slots, self.max_len = max_slots, max_len
        self._rid = itertools.count()
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * max_slots
        base = init_cache(cfg, 1, max_len)
        # stacked slot caches: every leaf gains a leading (max_slots,) axis
        self.cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (max_slots,) + a.shape).copy(),
            base)
        self._vdecode = jax.jit(jax.vmap(
            lambda cache, tok: decode_step(self.params, cache, tok, cfg),
            in_axes=(0, 0)))
        self._prefill = jax.jit(
            lambda batch: prefill(self.params, batch, cfg))

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        r = Request(next(self._rid), list(prompt), max_new_tokens, eos_id)
        self.queue.append(r)
        return r.rid

    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _insert_cache(self, slot: int, cache_one):
        """Pad the prefilled cache to max_len and write it into the slot."""
        def fit(path, stacked, one):
            names = [getattr(p, "key", None) for p in path]
            if names and names[-1] in ("k", "v") and one.ndim == 5:
                # (P, B=1, S, Hkv, Dh): pad prefill length S up to max_len
                pad = [(0, 0)] * one.ndim
                pad[2] = (0, self.max_len - one.shape[2])
                one = jnp.pad(one, pad)
            return stacked.at[slot].set(one)
        self.cache = jax.tree_util.tree_map_with_path(
            lambda p, s, o: fit(p, s, o), self.cache, cache_one)

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            # scope must surround the tracing call: dispatch decisions are
            # made at trace time and baked into the jitted computation
            with kernel_dispatch.backend_scope(self.bsn_backend):
                logits, cache_one = self._prefill({"tokens": toks})
            nxt = int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))
            req.generated.append(nxt)
            self._insert_cache(slot, cache_one)
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """Admit + one batched decode step. Returns completed requests."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []
        toks = np.zeros((self.max_slots, 1, 1), np.int32)
        for i in active:
            toks[i, 0, 0] = self.slots[i].generated[-1]
        with kernel_dispatch.backend_scope(self.bsn_backend):
            logits, self.cache = self._vdecode(self.cache, jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(
            logits[:, 0, 0, :self.cfg.vocab_size], axis=-1))
        done = []
        for i in active:
            r = self.slots[i]
            r.generated.append(int(nxt[i]))
            hit_eos = r.eos_id is not None and int(nxt[i]) == r.eos_id
            if hit_eos or len(r.generated) >= r.max_new_tokens \
                    or int(self.cache["pos"][i]) >= self.max_len - 1:
                r.done = True
                done.append(r)
                self.slots[i] = None
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        out = []
        for _ in range(max_steps):
            out += self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return out

"""Deterministic synthetic datasets.

``SyntheticLM`` — a first-order Markov language with a sparse, seeded
transition matrix: low-entropy enough that a small LM measurably learns
(loss drops well below the unigram entropy), giving the QAT experiments a
real signal without any offline corpus.

``SyntheticClassification`` — class-prototype images + noise, the stand-in
for MNIST/CIFAR in the paper-mechanism benchmarks (DESIGN.md §8: absolute
CIFAR numbers are out of reach offline; relative claims are validated).

Both are *stateless*: every batch is derived from (seed, step) — see
package docstring.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "SyntheticClassification", "host_batch"]


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    branching: int = 4          # out-degree of the Markov chain

    def _transitions(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, self.vocab_size,
                            (self.vocab_size, self.branching))

    def batch(self, step: int, batch_size: int) -> dict:
        """(tokens, targets) (B, S) int32 — pure function of step."""
        trans = jnp.asarray(self._transitions())
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k0, k1 = jax.random.split(key)
        state0 = jax.random.randint(k0, (batch_size,), 0, self.vocab_size)
        choice = jax.random.randint(k1, (batch_size, self.seq_len + 1), 0,
                                    self.branching)

        def step_fn(s, c):
            nxt = trans[s, c]
            return nxt, nxt

        _, seq = jax.lax.scan(step_fn, state0, choice.T)
        seq = jnp.moveaxis(seq, 0, 1)                  # (B, S+1)
        return {"tokens": seq[:, :-1].astype(jnp.int32),
                "targets": seq[:, 1:].astype(jnp.int32),
                "loss_mask": jnp.ones((batch_size, self.seq_len),
                                      jnp.float32)}

    def entropy_floor(self) -> float:
        """CE of the perfect model: log(branching) (uniform choice)."""
        return float(np.log(self.branching))


@dataclass(frozen=True)
class SyntheticClassification:
    """Labels from a fixed random *teacher MLP* over Gaussian inputs.

    Prototype-matching tasks are linearly separable (any quantization
    still scores ~100%); a nonlinear teacher makes representation capacity
    matter, so the paper's activation-quantization cliff (Table III) is
    actually observable.
    """
    n_classes: int = 10
    dim: int = 784
    seed: int = 0
    teacher_hidden: int = 48
    margin: float = 0.25        # drop ambiguous samples near the boundary

    def _teacher(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        w1 = rng.normal(0, 1 / np.sqrt(self.dim),
                        (self.dim, self.teacher_hidden)).astype(np.float32)
        w2 = rng.normal(0, 1 / np.sqrt(self.teacher_hidden),
                        (self.teacher_hidden, self.n_classes)).astype(np.float32)
        return w1, w2

    def batch(self, step: int, batch_size: int) -> dict:
        w1, w2 = map(jnp.asarray, self._teacher())
        key = jax.random.fold_in(jax.random.key(self.seed + 1), step)
        # oversample, keep confident examples (margin filter)
        n = batch_size * 2
        x = jax.random.normal(key, (n, self.dim))
        logits = jnp.tanh(x @ w1) @ w2
        top2 = jax.lax.top_k(logits, 2)[0]
        conf = top2[:, 0] - top2[:, 1]
        order = jnp.argsort(-conf)[:batch_size]
        x = x[order]
        y = jnp.argmax(logits[order], axis=-1)
        return {"x": x.astype(jnp.float32), "y": y.astype(jnp.int32)}


def host_batch(ds: SyntheticLM, step: int, global_batch: int,
               host_id: int = 0, n_hosts: int = 1) -> dict:
    """Each host materializes only its shard: fold host_id into the stream
    and take global_batch / n_hosts examples (stateless resharding: a job
    restarted on a different host count regenerates identical global data
    when global_batch is unchanged)."""
    assert global_batch % n_hosts == 0
    per_host = global_batch // n_hosts
    full = ds.batch(step, global_batch)
    lo = host_id * per_host
    return {k: v[lo:lo + per_host] for k, v in full.items()}

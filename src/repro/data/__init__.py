"""Data pipeline: stateless-seeded synthetic streams (no offline datasets).

Statelessness is the fault-tolerance property: batch(step) is a pure
function of (seed, step, shard), so a restarted/rescaled job resumes the
exact data order from the checkpointed step with no iterator state.
"""

from .synthetic import SyntheticLM, SyntheticClassification, host_batch

__all__ = ["SyntheticLM", "SyntheticClassification", "host_batch"]

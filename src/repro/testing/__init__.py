"""Test-support utilities shipped with the library.

``property_fallback`` is a miniature, deterministic stand-in for the
`hypothesis` API surface this repo uses.  The real dependency is declared
in ``requirements-test.txt``; in hermetic containers without it the test
suite degrades to the fallback (fixed pseudo-random example sweeps)
instead of erroring at collection.  See tests/conftest.py for the hook.
"""

from . import property_fallback

__all__ = ["property_fallback"]

"""Minimal deterministic fallback for the ``hypothesis`` API.

Implements exactly the surface the test suite uses — ``given``,
``settings``, and the strategies ``integers``, ``floats``, ``booleans``,
``just``, ``sampled_from``, ``lists``, ``tuples`` — by running each
property over a fixed number of pseudo-random examples.  Seeds derive
from the test's qualified name, so runs are reproducible and failures
name the falsifying example.  No shrinking, no database, no phases:
this is a degraded mode for containers without the real package, not a
replacement (``requirements-test.txt`` declares the real thing).

``install_as_hypothesis()`` registers synthetic ``hypothesis`` /
``hypothesis.strategies`` modules in ``sys.modules`` so unmodified
``from hypothesis import given`` imports keep working.
"""

from __future__ import annotations

import sys
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "install_as_hypothesis"]

_DEFAULT_MAX_EXAMPLES = 30


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class Strategy:
    """A sampler: ``example(rng)`` draws one value."""

    def __init__(self, draw, name: str):
        self._draw = draw
        self._name = name

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):
        return self._name


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float, *, allow_nan: bool = True,
           allow_infinity: bool = True) -> Strategy:
    del allow_nan, allow_infinity          # bounded draws are always finite
    return Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})")


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def just(value) -> Strategy:
    return Strategy(lambda rng: value, f"just({value!r})")


def sampled_from(elements) -> Strategy:
    pool = list(elements)
    return Strategy(lambda rng: pool[int(rng.integers(len(pool)))],
                    f"sampled_from({pool!r})")


def lists(elements: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return Strategy(draw, f"lists({elements!r}, {min_size}..{max_size})")


def tuples(*strats: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.example(rng) for s in strats),
                    f"tuples{strats!r}")


# ---------------------------------------------------------------------------
# decorators
# ---------------------------------------------------------------------------

def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Record run options on the function; other kwargs are accepted and
    ignored (the fallback has no deadlines, phases, or health checks)."""
    del deadline

    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: Strategy):
    """Run the property over a deterministic example sweep."""

    def deco(fn):
        def runner():
            opts = (getattr(runner, "_fallback_settings", None)
                    or getattr(fn, "_fallback_settings", None)
                    or {"max_examples": _DEFAULT_MAX_EXAMPLES})
            base = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(opts["max_examples"]):
                rng = np.random.default_rng([base, i])
                args = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__qualname__}: "
                        f"args={args!r}") from e

        # NOTE: no functools.wraps — __wrapped__ would make pytest read the
        # original signature and demand fixtures named after the arguments.
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis_fallback = True
        return runner
    return deco


# module-alias object so `from hypothesis import strategies as st` works
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.just = just
strategies.sampled_from = sampled_from
strategies.lists = lists
strategies.tuples = tuples


def install_as_hypothesis() -> None:
    """Register fallback ``hypothesis`` modules in ``sys.modules``."""
    if "hypothesis" in sys.modules:          # real package (or already done)
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                            filter_too_much="filter_too_much")
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
